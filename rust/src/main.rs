//! `tempo` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   train        — train one artifact (MLM on the synthetic corpus)
//!   compare      — baseline-vs-tempo loss curves (Fig 6a analogue)
//!   finetune     — MRPC-analogue classification trials (Fig 6b)
//!   experiments  — regenerate paper tables/figures (memmodel+perfmodel)
//!   max-batch    — capacity query for a (model, technique, gpu)
//!   autotempo    — §5.2 automatic application pass (`--placement
//!                  uniform|joint` switches to the placement search)
//!   placement    — joint (rewrite ∪ checkpoint) placement search,
//!                  printed as a per-layer plan table
//!   graph        — per-layer retained-tensor table (Fig 1) from the
//!                  layer-graph IR, with rewrite annotations
//!   schedule     — fwd+bwd execution timeline with live-bytes per op
//!                  event and the high-water mark, cross-checked
//!                  against the capacity model's fold
//!   artifacts    — list available artifacts (on-disk or builtin sim)
//!
//! Execution backend: `--backend sim` (default; deterministic, zero
//! artifacts needed) or `--backend pjrt` (requires `--features pjrt`
//! and `make artifacts`).

use std::path::PathBuf;

use tempo::autotempo::{coarse_pass, fine_search};
use tempo::config::{Gpu, ModelConfig, Technique, TrainingConfig};
use tempo::coordinator::{
    compare_variants, finetune_trials, CellFailure, ExperimentEngine, Trainer, TrainerOptions,
};
use tempo::memmodel::max_batch;
use tempo::report::{run_experiments, ALL_EXPERIMENTS};
use tempo::runtime::{ArtifactIndex, Backend, SimBackend};
use tempo::util::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

const USAGE: &str = "\
tempo — Tempo (NeurIPS'22) reproduction coordinator

USAGE:
  tempo train [--artifact NAME] [--steps N] [--lr F] [--seed N]
              [--config FILE] [--checkpoint-out PATH] [--resume PATH]
  tempo compare [--artifacts a,b,...] [--steps N] [--lr F] [--seed N] [--out CSV]
  tempo finetune [--artifact NAME] [--trials N] [--steps N] [--lr F] [--out CSV]
  tempo experiments (--all | --id ID) [--quiet]
  tempo max-batch --model NAME [--seq N] [--gpu 2080ti|v100|a100]
  tempo memory-report --model NAME [--seq N] [--batch N] [--finetune]
  tempo autotempo --model NAME [--seq N] [--gpu NAME] [--target-batch N]
                  [--placement uniform|joint] [--tp 1|2|4|8|auto]
                  [--probe measured] [--top K] [--seed N]
  tempo placement [MODEL] [--seq N] [--gpu NAME] [--target-batch N]
                  [--placement uniform|joint] [--tp 1|2|4|8|auto]
                  [--jobs N|auto] [--stats] [--json]
  tempo graph [MODEL] [--seq N] [--batch N] [--technique baseline|tempo|checkpoint]
              [--opts gelu,layernorm,dropout,softmax] [--pre-ln] [--causal] [--unfused]
              [--json]
  tempo schedule [MODEL] [--seq N] [--batch N] [--technique baseline|tempo|checkpoint]
              [--opts gelu,layernorm,dropout,softmax] [--finetune] [--serial-checkpoint]
              [--pre-ln] [--causal] [--unfused] [--gpu NAME] [--devices N] [--tp N]
              [--json]
  tempo artifacts [--dir DIR]

Common options:
  --backend sim|pjrt   execution engine (default: sim; pjrt requires the
                       `pjrt` cargo feature and on-disk artifacts)
  --jobs N|auto        worker threads for compare/finetune/experiments
                       sweeps and the placement/autotempo candidate
                       search (default: auto = one per core; results are
                       bit-identical for every N — see DESIGN.md
                       §Concurrency)
  --verbose            per-step progress lines in compare/finetune
                       sweeps (honored serially, i.e. with --jobs 1;
                       parallel workers stay quiet so output cannot
                       interleave)

Artifacts default to ./artifacts (override with --dir / TEMPO_ARTIFACTS);
when no artifacts/ exists, the builtin sim set is used.";

/// Which execution engine the user asked for.
enum BackendChoice {
    Sim,
    #[cfg(feature = "pjrt")]
    Pjrt,
}

fn backend_choice(args: &Args) -> tempo::Result<BackendChoice> {
    match args.get_or("backend", "sim").as_str() {
        "sim" => Ok(BackendChoice::Sim),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(BackendChoice::Pjrt),
        other => Err(tempo::Error::Invalid(format!(
            "unknown backend '{other}' (this build supports: sim{})",
            if cfg!(feature = "pjrt") { ", pjrt" } else { " — rebuild with --features pjrt for pjrt" }
        ))),
    }
}

/// Sweep worker pool from `--jobs` (default: one worker per core).
fn engine_from_args(args: &Args) -> tempo::Result<ExperimentEngine> {
    match args.get("jobs") {
        None => Ok(ExperimentEngine::auto()),
        Some("auto") | Some("0") => Ok(ExperimentEngine::auto()),
        Some(v) => {
            let n: usize = v.parse().map_err(|_| {
                tempo::Error::Invalid(format!("--jobs expects an integer or 'auto', got '{v}'"))
            })?;
            Ok(ExperimentEngine::new(n))
        }
    }
}

/// Report captured per-cell failures; `Err` when any cell failed so the
/// process exits non-zero *after* the surviving cells were reported.
fn report_failures(what: &str, failures: &[CellFailure]) -> tempo::Result<()> {
    if failures.is_empty() {
        return Ok(());
    }
    for f in failures {
        eprintln!("error: {what} {f}");
    }
    Err(tempo::Error::Backend(format!(
        "{} of the {what} cells failed (the rest completed and were reported above)",
        failures.len()
    )))
}

fn artifacts_dir(args: &Args) -> String {
    args.get("dir")
        .map(str::to_string)
        .or_else(|| std::env::var("TEMPO_ARTIFACTS").ok())
        .unwrap_or_else(|| "artifacts".into())
}

fn open_index(args: &Args) -> ArtifactIndex {
    let index = ArtifactIndex::load_or_builtin(artifacts_dir(args));
    if index.is_builtin() {
        eprintln!("note: no artifacts/ on disk — using the builtin sim artifact set");
    }
    index
}

fn parse_gpu(name: &str) -> tempo::Result<Gpu> {
    match name.to_ascii_lowercase().as_str() {
        "2080ti" | "rtx2080ti" => Ok(Gpu::Rtx2080Ti),
        "v100" => Ok(Gpu::V100),
        "a100" => Ok(Gpu::A100),
        other => Err(tempo::Error::Invalid(format!("unknown gpu '{other}'"))),
    }
}

/// Recover a boolean flag the in-tree Args parser may have mis-parsed
/// as an option: `--causal gpt2` (a bare flag followed by a non-flag
/// token) parses as causal="gpt2". Honor the flag AND hand the
/// swallowed token back as the positional model, so flag order never
/// changes the model priced (shared by `tempo graph`/`tempo schedule`).
fn recovered_flag(args: &Args, name: &str, positional_model: &mut Option<String>) -> bool {
    if args.flag(name) {
        return true;
    }
    if let Some(v) = args.get(name) {
        if positional_model.is_none() {
            *positional_model = Some(v.to_string());
        }
        return true;
    }
    false
}

fn parse_model(args: &Args) -> tempo::Result<ModelConfig> {
    let name = args.get_or("model", "bert-large");
    let mut cfg = ModelConfig::preset(&name)
        .ok_or_else(|| tempo::Error::Invalid(format!("unknown model preset '{name}'")))?;
    if let Some(s) = args.get("seq") {
        cfg = cfg.with_seq_len(s.parse().map_err(|_| tempo::Error::Invalid("--seq".into()))?);
    }
    if let Some(h) = args.get("hidden") {
        cfg = cfg.with_hidden(h.parse().map_err(|_| tempo::Error::Invalid("--hidden".into()))?)?;
    }
    if let Some(l) = args.get("layers") {
        cfg = cfg.with_layers(l.parse().map_err(|_| tempo::Error::Invalid("--layers".into()))?);
    }
    Ok(cfg)
}

fn training_config(args: &Args) -> tempo::Result<TrainingConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainingConfig::from_kv_file(path)?,
        None => TrainingConfig::default(),
    };
    if let Some(a) = args.get("artifact") {
        cfg.artifact = a.to_string();
    }
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.warmup_steps = args.get_usize("warmup", cfg.warmup_steps)?;
    cfg.peak_lr = args.get_f64("lr", cfg.peak_lr)?;
    cfg.seed = args.get_usize("seed", cfg.seed as usize)? as u64;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    cfg.log_every = args.get_usize("log-every", cfg.log_every)?;
    Ok(cfg)
}

fn run() -> tempo::Result<()> {
    // fail fast on malformed model knobs (TEMPO_UTIL_K etc.) instead of
    // panicking mid-sweep on the first priced cell
    tempo::perfmodel::validate_env_knobs()?;
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "compare" => cmd_compare(&args),
        "finetune" => cmd_finetune(&args),
        "experiments" => cmd_experiments(&args),
        "max-batch" => cmd_max_batch(&args),
        "memory-report" => cmd_memory_report(&args),
        "autotempo" => cmd_autotempo(&args),
        "placement" => cmd_placement(&args),
        "graph" => cmd_graph(&args),
        "schedule" => cmd_schedule(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_train(args: &Args) -> tempo::Result<()> {
    let index = open_index(args);
    match backend_choice(args)? {
        BackendChoice::Sim => train_with(&SimBackend::new(), &index, args),
        #[cfg(feature = "pjrt")]
        BackendChoice::Pjrt => {
            train_with(&tempo::runtime::PjrtBackend::cpu()?, &index, args)
        }
    }
}

fn train_with<B: Backend>(backend: &B, index: &ArtifactIndex, args: &Args) -> tempo::Result<()> {
    let cfg = training_config(args)?;
    println!("loading artifact {} (backend: {}) …", cfg.artifact, backend.name());
    let artifact = index.open(&cfg.artifact)?;
    let opts = TrainerOptions {
        checkpoint_out: args.get("checkpoint-out").map(PathBuf::from),
        resume_from: args.get("resume").map(PathBuf::from),
        verbose: true,
    };
    let mut trainer = Trainer::new(backend, artifact, cfg, opts)?;
    let state = trainer.state()?;
    println!(
        "params: {} ({:.1} M) — starting",
        state.param_count(),
        state.param_count() as f64 / 1e6
    );
    trainer.run()?;
    let m = trainer.metrics();
    println!(
        "done: final loss {:.4} | ema {:.4} | {:.1} seq/s | mean step {:?}",
        m.last_loss().unwrap_or(f64::NAN),
        m.ema_loss().unwrap_or(f64::NAN),
        m.throughput(),
        m.mean_step_time(),
    );
    if let Some(out) = args.get("out") {
        std::fs::write(out, m.to_csv())?;
        println!("loss curve → {out}");
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> tempo::Result<()> {
    let index = open_index(args);
    match backend_choice(args)? {
        BackendChoice::Sim => compare_with(&SimBackend::new(), &index, args),
        #[cfg(feature = "pjrt")]
        BackendChoice::Pjrt => {
            compare_with(&tempo::runtime::PjrtBackend::cpu()?, &index, args)
        }
    }
}

fn compare_with<B: Backend>(backend: &B, index: &ArtifactIndex, args: &Args) -> tempo::Result<()> {
    let cfg = training_config(args)?;
    let engine = engine_from_args(args)?;
    let names_raw = args.get_or("artifacts", "bert_tiny_baseline,bert_tiny_tempo");
    let names: Vec<&str> = names_raw.split(',').collect();
    // stdout is byte-identical for every --jobs value: worker count goes
    // to stderr, per-step progress lines stay off (--verbose opts in,
    // serial only).
    eprintln!("note: {} sweep worker(s)", engine.jobs());
    println!("comparing {names:?} over {} steps (shared data/masks)", cfg.steps);
    let verbose = args.flag("verbose");
    let result = compare_variants(backend, index, &names, &cfg, &engine, verbose)?;
    for c in &result.curves {
        println!(
            "  {:<24} endpoint loss {:.4}",
            c.artifact,
            c.endpoint((cfg.steps / 10).max(5))
        );
    }
    println!(
        "max endpoint deviation vs {}: {:.3}% (paper Fig 6a: ≤ 0.5%)",
        result.curves[0].artifact,
        100.0 * result.max_endpoint_rel_diff
    );
    if let Some(out) = args.get("out") {
        let mut csv = String::from("step");
        for c in &result.curves {
            csv.push_str(&format!(",{}", c.artifact));
        }
        csv.push('\n');
        for i in 0..result.curves[0].losses.len() {
            csv.push_str(&i.to_string());
            for c in &result.curves {
                csv.push_str(&format!(",{:.6}", c.losses[i]));
            }
            csv.push('\n');
        }
        std::fs::write(out, csv)?;
        println!("curves → {out}");
    }
    report_failures("compare", &result.failures)
}

fn cmd_finetune(args: &Args) -> tempo::Result<()> {
    let index = open_index(args);
    match backend_choice(args)? {
        BackendChoice::Sim => finetune_with(&SimBackend::new(), &index, args),
        #[cfg(feature = "pjrt")]
        BackendChoice::Pjrt => {
            finetune_with(&tempo::runtime::PjrtBackend::cpu()?, &index, args)
        }
    }
}

fn finetune_with<B: Backend>(backend: &B, index: &ArtifactIndex, args: &Args) -> tempo::Result<()> {
    let artifact_name = args.get_or("artifact", "cls_tiny_tempo");
    let trials = args.get_usize("trials", 3)?;
    let steps = args.get_usize("steps", 60)?;
    let eval_every = args.get_usize("eval-every", 20)?;
    let lr = args.get_f64("lr", 5e-4)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let artifact = index.open(&artifact_name)?;
    let engine = engine_from_args(args)?;
    eprintln!("note: {} sweep worker(s)", engine.jobs());
    println!("fine-tuning {artifact_name}: {trials} trials × {steps} steps");
    let verbose = args.flag("verbose");
    let result = finetune_trials(
        backend,
        &artifact,
        trials,
        steps,
        eval_every,
        lr,
        seed,
        &engine,
        verbose,
    )?;
    let (lo, med, hi) = result.final_band();
    println!("final accuracy band: min {lo:.3} / median {med:.3} / max {hi:.3}");
    if let Some(out) = args.get("out") {
        let mut csv = String::from("trial,eval_point,accuracy\n");
        for (i, t) in result.trials.iter().enumerate() {
            for (j, a) in t.accuracy.iter().enumerate() {
                csv.push_str(&format!("{i},{j},{a:.4}\n"));
            }
        }
        std::fs::write(out, csv)?;
        println!("curves → {out}");
    }
    report_failures("finetune", &result.failures)
}

fn cmd_experiments(args: &Args) -> tempo::Result<()> {
    let quiet = args.flag("quiet");
    let engine = engine_from_args(args)?;
    let ids: Vec<&str> = if args.flag("all") || args.get("id").is_none() {
        ALL_EXPERIMENTS.iter().map(|e| e.id).collect()
    } else {
        vec![args.get("id").unwrap()]
    };
    // Tables are built concurrently; printing and CSV writing happen
    // here, serially in id order, so the output is identical for every
    // --jobs setting.
    let mut failures = Vec::new();
    for (index, (id, result)) in run_experiments(&ids, &engine).into_iter().enumerate() {
        match result {
            Ok(table) => {
                if !quiet {
                    println!("{}", table.render());
                }
                // CSV IO errors are isolated like compute errors: the
                // remaining tables still print and get reported.
                match table.write_csv(&id) {
                    Ok(path) => println!("[{id}] → {}", path.display()),
                    Err(e) => failures.push(CellFailure {
                        index,
                        label: id,
                        error: format!("writing CSV failed: {e}"),
                    }),
                }
            }
            Err(e) => failures.push(CellFailure { index, label: id, error: e.to_string() }),
        }
    }
    report_failures("experiments", &failures)
}

fn cmd_max_batch(args: &Args) -> tempo::Result<()> {
    let cfg = parse_model(args)?;
    let gpu = parse_gpu(&args.get_or("gpu", "2080ti"))?;
    println!("{} @ S={} on {}:", cfg.name, cfg.seq_len, gpu.name());
    for tech in Technique::all() {
        let fit = max_batch(&cfg, tech, gpu);
        println!(
            "  {:<11} max batch {:>5}  ({:.2} GB at max, {:.2} GB would overflow)",
            tech.name(),
            fit.max_batch,
            fit.bytes_at_max as f64 / 1e9,
            fit.bytes_over as f64 / 1e9
        );
    }
    Ok(())
}

fn cmd_memory_report(args: &Args) -> tempo::Result<()> {
    use tempo::memmodel::ModelFootprint;
    let cfg = parse_model(args)?;
    let batch = args.get_usize("batch", 8)?;
    let finetune = args.flag("finetune");
    println!(
        "{} @ S={} B={} ({}) — per-GPU bytes:",
        cfg.name,
        cfg.seq_len,
        batch,
        if finetune { "fine-tune head" } else { "MLM head" }
    );
    for tech in Technique::all() {
        let mut fp = ModelFootprint::new(cfg.clone(), tech);
        if finetune {
            fp = fp.finetune();
        }
        let bd = fp.breakdown(batch);
        println!("  {}:", tech.name());
        for (label, bytes) in [
            ("params", bd.params),
            ("grads", bd.grads),
            ("optimizer", bd.optimizer),
            ("encoder activations", bd.encoder_activations),
            ("other activations", bd.other_activations),
            (bd.transient_label, bd.transient),
        ] {
            println!(
                "    {:<20} {:>9.3} GB  ({:>5.1}%)",
                label,
                bytes as f64 / 1e9,
                100.0 * bytes as f64 / bd.total() as f64
            );
        }
        println!("    {:<20} {:>9.3} GB", "TOTAL", bd.total() as f64 / 1e9);
    }
    Ok(())
}

/// Parse the shared `--placement uniform|joint` option.
fn parse_placement(name: &str) -> tempo::Result<tempo::autotempo::PlacementMode> {
    tempo::autotempo::PlacementMode::parse(name).ok_or_else(|| {
        tempo::Error::Invalid(format!("unknown placement mode '{name}' (uniform|joint)"))
    })
}

/// Parse the shared `--tp 1|2|4|8|auto` tensor-parallel degree policy
/// (default: the shard-free search).
fn parse_tp_policy(args: &Args) -> tempo::Result<tempo::autotempo::TpPolicy> {
    match args.get("tp") {
        None => Ok(tempo::autotempo::TpPolicy::Fixed(1)),
        Some(v) => tempo::autotempo::TpPolicy::parse(v).ok_or_else(|| {
            tempo::Error::Invalid(format!("--tp expects one of 1|2|4|8|auto, got '{v}'"))
        }),
    }
}

/// Parse the shared optional `--target-batch N`.
fn parse_target_batch(args: &Args) -> tempo::Result<Option<usize>> {
    match args.get("target-batch") {
        None => Ok(None),
        Some(tb) => tb
            .parse()
            .map(Some)
            .map_err(|_| tempo::Error::Invalid("--target-batch expects an integer".into())),
    }
}

fn cmd_autotempo(args: &Args) -> tempo::Result<()> {
    let cfg = parse_model(args)?;
    let gpu = parse_gpu(&args.get_or("gpu", "2080ti"))?;
    if let Some(probe) = args.get("probe") {
        // measured probe: execute the analytically best candidates on
        // the kernel backend and re-rank by wall clock — §Kernels
        if probe != "measured" {
            return Err(tempo::Error::Invalid(format!(
                "unknown probe mode '{probe}' (supported: measured)"
            )));
        }
        let top = args.get_usize("top", 3)?;
        let seed = args.get_usize("seed", 42)? as u64;
        let engine = engine_from_args(args)?;
        let r = tempo::autotempo::measured_probe(&cfg, gpu, top, seed, &engine)?;
        println!(
            "measured probe: ran top {} of {} candidates at {} \
             (H={} S={} L={} B={}, {} timed steps each)",
            r.rows.len(),
            r.candidates,
            r.probe_cfg.name,
            r.probe_cfg.hidden,
            r.probe_cfg.seq_len,
            r.probe_cfg.layers,
            tempo::autotempo::PROBE_BATCH,
            tempo::autotempo::PROBE_STEPS,
        );
        for (i, row) in r.rows.iter().enumerate() {
            println!(
                "  {}. {:<16} {:>8.3} ms/step  peak {:>7.3} MB (model {:>7.3} MB, drift {:>+6.1}%)  \
                 rel-time drift {:>+6.1}%  analytic rank {}{}",
                i + 1,
                row.label,
                row.measured_step_s * 1e3,
                row.measured_peak_bytes as f64 / 1e6,
                row.modeled_peak_bytes as f64 / 1e6,
                row.peak_drift.drift_pct(),
                row.time_drift.drift_pct(),
                row.analytic_rank + 1,
                if row.host_peak_bytes > 0 {
                    format!(", host stash {:.3} MB", row.host_peak_bytes as f64 / 1e6)
                } else {
                    String::new()
                },
            );
        }
        let d = &r.decision;
        println!("{}", d.rationale);
        println!(
            "  plan at full dims: rewrites on {}/{} layers, {} checkpointed, {} offloaded, \
             max batch {}, {:.2} seq/s",
            d.plan.applied_layers(),
            cfg.layers,
            d.plan.checkpointed_layers(),
            d.plan.offloaded_layers(),
            d.max_batch,
            d.throughput,
        );
        return Ok(());
    }
    if let Some(mode_name) = args.get("placement") {
        // joint (rewrite ∪ checkpoint) placement search — §Placement
        let mode = parse_placement(mode_name)?;
        let tp = parse_tp_policy(args)?;
        let target = parse_target_batch(args)?;
        let engine = engine_from_args(args)?;
        let d = tempo::autotempo::placement_search_jobs(&cfg, gpu, mode, tp, target, true, &engine);
        println!("placement search: {}", d.rationale);
        println!(
            "  plan: rewrites on {}/{} layers, {} checkpointed, {} offloaded, {} sharded \
             (tp {}), max batch {}, {:.2} seq/s at B={}",
            d.plan.applied_layers(),
            cfg.layers,
            d.plan.checkpointed_layers(),
            d.plan.offloaded_layers(),
            d.plan.sharded_layers(),
            d.tp,
            d.max_batch,
            d.throughput,
            d.eval_batch,
        );
        println!("  (`tempo placement` prints the chosen per-layer plan as a table)");
        return Ok(());
    }
    match args.get("target-batch") {
        None => {
            let d = coarse_pass(&cfg, gpu);
            println!("coarse pass: {}", d.rationale);
            println!(
                "  plan: tempo on {}/{} layers, max batch {}, {:.2} seq/s",
                d.plan.applied_layers(),
                cfg.layers,
                d.max_batch,
                d.throughput
            );
        }
        Some(tb) => {
            let target: usize =
                tb.parse().map_err(|_| tempo::Error::Invalid("--target-batch".into()))?;
            let d = fine_search(&cfg, gpu, target);
            println!("fine-grained search: {}", d.rationale);
            println!(
                "  plan: tempo on {}/{} layers, max batch {}, {:.2} seq/s",
                d.plan.applied_layers(),
                cfg.layers,
                d.max_batch,
                d.throughput
            );
        }
    }
    Ok(())
}

/// `tempo placement` — the joint-placement search's debugging surface:
/// run the (rewrite ∪ checkpoint ∪ offload) placement search and print
/// the chosen per-layer plan as a table, with the capacity model's
/// breakdown of the winning plan.
fn cmd_placement(args: &Args) -> tempo::Result<()> {
    use tempo::autotempo::{placement_search_jobs, PlacementMode};
    use tempo::config::OptimizationSet;
    use tempo::memmodel::plan_breakdown;
    use tempo::report::Table;
    use tempo::util::Json;

    let mut positional_model = args.positional.get(1).cloned();
    let want_json = recovered_flag(args, "json", &mut positional_model);
    let want_stats = recovered_flag(args, "stats", &mut positional_model);

    let mut args = args.clone();
    if let Some(name) = positional_model {
        args.options.entry("model".into()).or_insert(name);
    }
    let cfg = parse_model(&args)?;
    let gpu = parse_gpu(&args.get_or("gpu", "2080ti"))?;
    let target = parse_target_batch(&args)?;
    let tp = parse_tp_policy(&args)?;
    let engine = engine_from_args(&args)?;
    let mode = match args.get("placement") {
        None => PlacementMode::Joint,
        Some(name) => parse_placement(name)?,
    };

    // snapshot the plan-pricing cache counters so --stats reports this
    // search's hits/misses, not the process-lifetime totals
    let cache_baseline = want_stats.then(tempo::graph::cache_stats);
    let d = placement_search_jobs(&cfg, gpu, mode, tp, target, true, &engine);
    let mut t = Table::new(
        format!(
            "Placement — {} @ S={} on {} ({} search)",
            cfg.name,
            cfg.seq_len,
            gpu.name(),
            mode.name()
        ),
        &["layer", "rewrites", "residency"],
    );
    for l in 0..cfg.layers {
        let res = d.plan.residency(l);
        t.row(vec![
            format!("enc{l}"),
            // checkpointed layers replay the unoptimized block, so
            // their rewrite column shows the recompute; offloaded
            // layers run their rewrites (they shrink the shipped bytes)
            if res.is_checkpoint() {
                "(recomputed)".into()
            } else {
                d.plan.per_layer.get(l).copied().unwrap_or_else(OptimizationSet::none).label()
            },
            res.label().to_string(),
        ]);
    }
    // breakdown of the winning plan at its max batch (B=1 when nothing fits)
    let bd = plan_breakdown(&cfg, &d.plan.schedule_plan(), d.max_batch.max(1));

    if want_json {
        // machine-readable mode: one JSON document, nothing else on
        // stdout (round-trips through report::Table::from_json)
        let mut fields = vec![
            ("model", Json::str(cfg.name.clone())),
            ("seq_len", Json::num(cfg.seq_len as f64)),
            ("gpu", Json::str(gpu.name())),
            // SPMD replicas: the plan, batch and peak below are all
            // per device; only the comm lane couples the devices
            ("devices", Json::num(gpu.spec().devices as f64)),
            ("mode", Json::str(mode.name())),
            // resolved shard degree of the winner (scale-up domain,
            // orthogonal to the data-parallel `devices` above)
            ("tp", Json::num(d.tp as f64)),
            ("max_batch", Json::num(d.max_batch as f64)),
            ("eval_batch", Json::num(d.eval_batch as f64)),
            ("throughput_seqs_per_s", Json::num(d.throughput)),
            ("checkpointed_layers", Json::num(d.plan.checkpointed_layers() as f64)),
            ("offloaded_layers", Json::num(d.plan.offloaded_layers() as f64)),
            ("sharded_layers", Json::num(d.plan.sharded_layers() as f64)),
            ("applied_layers", Json::num(d.plan.applied_layers() as f64)),
            ("candidates", Json::num(d.stats.enumerated as f64)),
            ("pruned_dominated", Json::num(d.stats.pruned as f64)),
            ("priced", Json::num(d.stats.priced as f64)),
            ("peak_bytes", Json::num(bd.total() as f64)),
            ("high_water", Json::str(bd.transient_label)),
        ];
        if let Some(base) = &cache_baseline {
            let caches = tempo::graph::cache_stats_since(base)
                .into_iter()
                .map(|(name, s)| {
                    (
                        name,
                        Json::obj(vec![
                            ("entries", Json::num(s.entries as f64)),
                            ("hits", Json::num(s.hits as f64)),
                            ("misses", Json::num(s.misses as f64)),
                            ("approx_bytes", Json::num(s.approx_bytes as f64)),
                        ]),
                    )
                })
                .collect();
            fields.push(("caches", Json::obj(caches)));
        }
        fields.push(("table", t.to_json()));
        let doc = Json::obj(fields);
        println!("{}", doc.pretty());
        return Ok(());
    }

    println!("{}", t.render());
    println!("{}", d.rationale);
    println!(
        "max batch {} per device at tp {} ({:.2} seq/s at B={}); per-device peak {:.3} GB at B={} \
         on {} ×{}, high water: {}",
        d.max_batch,
        d.tp,
        d.throughput,
        d.eval_batch,
        bd.total() as f64 / 1e9,
        d.max_batch.max(1),
        gpu.name(),
        gpu.spec().devices,
        bd.transient_label,
    );
    if let Some(base) = &cache_baseline {
        // hit/miss counters of the plan-pricing caches scoped to the
        // search this command just ran (hit counts depend on --jobs
        // interleaving, which is why the decision — pinned jobs-
        // invariant — never reads them)
        for (name, s) in tempo::graph::cache_stats_since(base) {
            println!(
                "cache {name}: {} entries, {} hits, {} misses, ~{:.1} KB resident",
                s.entries,
                s.hits,
                s.misses,
                s.approx_bytes as f64 / 1e3,
            );
        }
    }
    Ok(())
}

/// `tempo graph` — the Fig 1 reproduction and the layer-graph IR's
/// debugging surface: which tensors one encoder layer retains for
/// backward, and which rewrite removed/added each.
fn cmd_graph(args: &Args) -> tempo::Result<()> {
    use tempo::config::OptimizationSet;
    use tempo::graph::{
        block_rows, encoder_block_with, live_totals, Lowering, SegmentCheckpoint, Topology,
    };
    use tempo::memmodel::layer_activation_bytes;
    use tempo::report::tensor_rows_table;

    let mut positional_model = args.positional.get(1).cloned();
    let want_pre_ln = recovered_flag(args, "pre-ln", &mut positional_model);
    let want_causal = recovered_flag(args, "causal", &mut positional_model);
    let want_unfused = recovered_flag(args, "unfused", &mut positional_model);
    let want_json = recovered_flag(args, "json", &mut positional_model);

    // model: positional (`tempo graph gpt2`) or the --model option
    let mut args = args.clone();
    if let Some(name) = positional_model {
        args.options.entry("model".into()).or_insert(name);
    }
    let cfg = parse_model(&args)?;
    let batch = args.get_usize("batch", 1)?;

    // rewrite set: --technique, refined by --opts gelu,layernorm,…
    let technique = args.get_or("technique", "tempo");
    let mut opts = match technique.as_str() {
        "baseline" => OptimizationSet::none(),
        "tempo" => OptimizationSet::full(),
        "checkpoint" => OptimizationSet::none(),
        other => {
            return Err(tempo::Error::Invalid(format!(
                "unknown technique '{other}' (baseline|tempo|checkpoint)"
            )))
        }
    };
    if let Some(list) = args.get("opts") {
        opts = OptimizationSet::none();
        for which in list.split(',').filter(|s| !s.is_empty()) {
            let one = OptimizationSet::only(which).ok_or_else(|| {
                tempo::Error::Invalid(format!(
                    "unknown optimization '{which}' (gelu|layernorm|dropout|softmax)"
                ))
            })?;
            opts = opts.union(one);
        }
    }

    // lowering rules: model defaults, overridable from the CLI
    let mut lowering = Lowering::for_model(&cfg);
    if want_pre_ln {
        lowering.topology = Topology::PreLn;
    }
    if want_causal {
        lowering.causal_census = true;
    }
    if want_unfused {
        lowering.unfused_attention = true;
    }

    let graph = encoder_block_with(&cfg, lowering);
    let t = tensor_rows_table(
        format!(
            "Fig 1 — retained tensors, one {} layer @ S={} B={} ({})",
            cfg.name,
            cfg.seq_len,
            batch,
            opts.label()
        ),
        block_rows(&graph, opts, batch),
    );
    let totals = live_totals(&graph, opts, batch);

    if want_json {
        // machine-readable mode: one JSON document, nothing else on
        // stdout (round-trips through report::Table::from_json)
        use tempo::util::Json;
        let doc = Json::obj(vec![
            ("model", Json::str(cfg.name.clone())),
            ("seq_len", Json::num(cfg.seq_len as f64)),
            ("batch", Json::num(batch as f64)),
            ("opts", Json::str(opts.label())),
            ("table", t.to_json()),
            (
                "totals",
                Json::obj(vec![
                    ("float_bytes", Json::num(totals.float_bytes as f64)),
                    ("mask_bytes", Json::num(totals.mask_bytes as f64)),
                    ("stat_bytes", Json::num(totals.stat_bytes as f64)),
                    ("total_bytes", Json::num(totals.total() as f64)),
                ]),
            ),
        ]);
        println!("{}", doc.pretty());
        return Ok(());
    }

    println!("{}", t.render());
    println!(
        "per-layer retained: {:.3} MB fp32 maps + {:.3} MB masks + {:.3} MB stats = {:.3} MB",
        totals.float_bytes as f64 / 1e6,
        totals.mask_bytes as f64 / 1e6,
        totals.stat_bytes as f64 / 1e6,
        totals.total() as f64 / 1e6,
    );
    println!(
        "encoder total (L={}): {:.3} GB",
        cfg.layers,
        cfg.layers as f64 * totals.total() as f64 / 1e9
    );
    if lowering == Lowering::for_model(&cfg) {
        // under the default lowering the table must agree with the
        // capacity model's fold — say so, as a live cross-check
        let fold = layer_activation_bytes(&cfg, batch, opts);
        println!(
            "memmodel cross-check: {} (fold {} bytes vs table {} bytes)",
            if fold.total() == totals.total() { "OK" } else { "MISMATCH" },
            fold.total(),
            totals.total()
        );
    }
    if technique == "checkpoint" {
        // same lowering as the table above, so the numbers agree
        let ck = SegmentCheckpoint::of(&graph.summarize(OptimizationSet::none()));
        println!(
            "checkpoint segment rewrite: store only the block input \
             ({:.3} MB/layer), transient recompute live set {:.3} MB",
            ck.stored_bytes(batch as u64) as f64 / 1e6,
            ck.transient_bytes(batch as u64) as f64 / 1e6,
        );
    }
    Ok(())
}

/// `tempo schedule` — the execution-schedule layer's debugging surface
/// (mirror of `tempo graph`): the fwd+bwd op timeline with per-event
/// alloc/free/live bytes and the step's high-water mark, cross-checked
/// live against the capacity model's fold.
fn cmd_schedule(args: &Args) -> tempo::Result<()> {
    use tempo::config::OptimizationSet;
    use tempo::graph::{
        lower_step, schedule_summary_with, Lowering, Residency, SchedulePlan, Topology,
    };
    use tempo::memmodel::ModelFootprint;
    use tempo::report::Table;
    use tempo::util::Json;

    let mut positional_model = args.positional.get(1).cloned();
    let want_pre_ln = recovered_flag(args, "pre-ln", &mut positional_model);
    let want_causal = recovered_flag(args, "causal", &mut positional_model);
    let want_unfused = recovered_flag(args, "unfused", &mut positional_model);
    let want_json = recovered_flag(args, "json", &mut positional_model);
    let want_serial = recovered_flag(args, "serial-checkpoint", &mut positional_model);
    let want_finetune = recovered_flag(args, "finetune", &mut positional_model);

    let mut args = args.clone();
    if let Some(name) = positional_model {
        args.options.entry("model".into()).or_insert(name);
    }
    let cfg = parse_model(&args)?;
    let batch = args.get_usize("batch", 1)?;
    let mlm = !want_finetune;

    let technique_name = args.get_or("technique", "tempo");
    let technique = match technique_name.as_str() {
        "baseline" => Technique::Baseline,
        "tempo" => Technique::Tempo,
        "checkpoint" => Technique::Checkpoint,
        other => {
            return Err(tempo::Error::Invalid(format!(
                "unknown technique '{other}' (baseline|tempo|checkpoint)"
            )))
        }
    };
    let mut plan = SchedulePlan::for_technique(&cfg, technique, mlm);
    let mut custom_opts: Option<OptimizationSet> = None;
    if let Some(list) = args.get("opts") {
        if technique == Technique::Checkpoint {
            return Err(tempo::Error::Invalid(
                "checkpointing recomputes the unoptimized block; --opts applies to baseline/tempo"
                    .into(),
            ));
        }
        let mut opts = OptimizationSet::none();
        for which in list.split(',').filter(|s| !s.is_empty()) {
            let one = OptimizationSet::only(which).ok_or_else(|| {
                tempo::Error::Invalid(format!(
                    "unknown optimization '{which}' (gelu|layernorm|dropout|softmax)"
                ))
            })?;
            opts = opts.union(one);
        }
        plan = SchedulePlan::uniform(&cfg, opts, mlm);
        custom_opts = Some(opts);
    }
    if want_serial {
        plan = plan.serial();
    }
    let tp = args.get_usize("tp", 1)?;
    if tp != 1 {
        plan = plan.with_tp(tp);
        if plan.resolved_tp(&cfg) > 1 {
            // shard every resident encoder layer so the timeline shows
            // the in-block collectives; checkpointed/offloaded layers
            // keep their residency arm
            plan.residency.resize(cfg.layers, Residency::Resident);
            for m in &mut plan.residency {
                if *m == Residency::Resident {
                    *m = Residency::Shard;
                }
            }
        } else {
            eprintln!(
                "note: tp {tp} does not divide {}'s heads/hidden/intermediate — \
                 lowering the unsharded timeline",
                cfg.name
            );
        }
    }
    let resolved_tp = plan.resolved_tp(&cfg);

    // lowering rules: model defaults, overridable from the CLI
    let mut lowering = Lowering::for_model(&cfg);
    if want_pre_ln {
        lowering.topology = Topology::PreLn;
    }
    if want_causal {
        lowering.causal_census = true;
    }
    if want_unfused {
        lowering.unfused_attention = true;
    }

    let schedule = lower_step(&cfg, &plan, lowering);
    let tl = schedule.timeline(batch);
    let summary = schedule_summary_with(&cfg, &plan, lowering);

    // comm lane: the data-parallel rig this schedule would run on —
    // one timeline replica per device, gradient buckets on the comm
    // lane (`--devices 1` turns the collective off entirely)
    let gpu = parse_gpu(&args.get_or("gpu", "2080ti"))?;
    let spec = gpu.spec().with_devices(args.get_usize("devices", gpu.spec().devices)?);
    let lanes =
        (batch > 0).then(|| tempo::perfmodel::plan_lane_times(&cfg, &plan, &spec, batch));

    let mb = |bytes: u64| format!("{:.3}", bytes as f64 / 1e6);
    let mut t = Table::new(
        format!(
            "Execution schedule — {} @ S={} B={} ({})",
            cfg.name,
            cfg.seq_len,
            batch,
            plan.label()
        ),
        &["#", "ev", "lane", "segment", "op", "alloc MB", "free MB", "live MB", ""],
    );
    for (i, (e, p)) in schedule.events.iter().zip(&tl.points).enumerate() {
        t.row(vec![
            i.to_string(),
            e.kind.label().to_string(),
            e.lane.label().to_string(),
            e.segment.label(),
            e.name.to_string(),
            mb(p.alloc_bytes),
            mb(p.free_bytes),
            mb(p.live_bytes),
            if i == tl.peak_event { "<- peak".into() } else { String::new() },
        ]);
    }

    // the capacity model's fold over the same plan (the live cross-check)
    let mut fp = match (technique, custom_opts) {
        (Technique::Checkpoint, _) => ModelFootprint::new(cfg.clone(), Technique::Checkpoint),
        (_, Some(o)) => ModelFootprint::with_opts(cfg.clone(), o),
        (tech, None) => ModelFootprint::new(cfg.clone(), tech),
    };
    if want_finetune {
        fp = fp.finetune();
    }
    let fold = fp.total_bytes(batch);
    let default_lowering = lowering == Lowering::for_model(&cfg);
    let serial_divergence = want_serial && technique == Technique::Checkpoint;

    if want_json {
        // machine-readable mode: one JSON document, nothing else on
        // stdout (round-trips through report::Table::from_json)
        let mut fields = vec![
            ("model", Json::str(cfg.name.clone())),
            ("seq_len", Json::num(cfg.seq_len as f64)),
            ("batch", Json::num(batch as f64)),
            ("plan", Json::str(plan.label())),
            ("gpu", Json::str(gpu.name())),
            // per-device peak: every replica holds the full state
            ("devices", Json::num(spec.devices as f64)),
            // resolved shard degree (scale-up domain within a replica)
            ("tp", Json::num(resolved_tp as f64)),
            ("grad_buckets", Json::num(schedule.grad_buckets.len() as f64)),
            ("peak_bytes", Json::num(tl.peak_bytes as f64)),
            ("peak_event", Json::num(tl.peak_event as f64)),
            ("high_water", Json::str(summary.high_water)),
            // the capacity model always prices the DEFAULT lowering and
            // the default (overlapped) checkpoint semantics — flag both
            // so consumers know when peak_bytes may legitimately differ
            ("memmodel_total_bytes", Json::num(fold as f64)),
            ("default_lowering", Json::Bool(default_lowering)),
            ("serial_checkpoint_divergence", Json::Bool(serial_divergence)),
        ];
        if let Some(lt) = lanes {
            // lane pricing (default lowering, like the capacity model)
            fields.push(("step_s", Json::num(lt.step)));
            fields.push(("comm_total_s", Json::num(lt.comm_total)));
            fields.push(("comm_exposed_s", Json::num(lt.comm_exposed)));
            fields.push(("hidden_recompute_s", Json::num(lt.hidden_recompute)));
            fields.push(("host_total_s", Json::num(lt.host_total)));
            fields.push(("host_exposed_s", Json::num(lt.host_exposed)));
            fields.push(("tp_total_s", Json::num(lt.tp_total)));
            fields.push(("tp_exposed_s", Json::num(lt.tp_exposed)));
        }
        fields.push(("table", t.to_json()));
        let doc = Json::obj(fields);
        println!("{}", doc.pretty());
        return Ok(());
    }

    println!("{}", t.render());
    println!(
        "peak live: {:.3} GB at event {} ({}.{}, {})",
        tl.peak_bytes as f64 / 1e9,
        tl.peak_event,
        schedule.events[tl.peak_event].segment.label(),
        schedule.events[tl.peak_event].name,
        summary.high_water,
    );
    if default_lowering && resolved_tp > 1 {
        // the capacity model's static fold prices the unsharded plan;
        // a sharded timeline's per-device peak legitimately undercuts it
        println!(
            "note: tensor-parallel timeline (tp {resolved_tp}); the capacity model's fold \
             prices the unsharded plan"
        );
    } else if default_lowering {
        if serial_divergence {
            // the enumerated divergence: serial checkpointing never
            // holds the head activations and a recompute inventory at
            // once, so its true peak undercuts the static sum
            println!(
                "memmodel static sum: {:.3} GB — serial checkpointing peaks {:.3} MB lower \
                 (no re-forward prefetch, so the head activations and the recompute \
                 inventory are never simultaneously live)",
                fold as f64 / 1e9,
                (fold - tl.peak_bytes) as f64 / 1e6,
            );
        } else {
            println!(
                "memmodel cross-check: {} (fold {} bytes vs timeline peak {} bytes)",
                if fold == tl.peak_bytes { "OK" } else { "MISMATCH" },
                fold,
                tl.peak_bytes
            );
        }
    } else {
        println!(
            "note: lowering overridden; the capacity and lane models price the default lowering"
        );
    }
    if let Some(lt) = lanes {
        if spec.devices > 1 && spec.allreduce_bw.is_some() {
            println!(
                "comm lane on {} ×{}: {} grad buckets, all-reduce {:.2} ms/step, {:.2} ms exposed \
                 beyond backward; per-device step {:.2} ms ({:.2} ms compute{})",
                gpu.name(),
                spec.devices,
                schedule.grad_buckets.len(),
                lt.comm_total * 1e3,
                lt.comm_exposed * 1e3,
                lt.step * 1e3,
                lt.compute * 1e3,
                if lt.hidden_recompute > 0.0 {
                    format!(
                        ", {:.2} ms recompute hidden under covering backward",
                        lt.hidden_recompute * 1e3
                    )
                } else {
                    String::new()
                },
            );
        } else {
            println!(
                "comm lane on {} ×{}: single-device rig — no collective traffic; step {:.2} ms",
                gpu.name(),
                spec.devices,
                lt.step * 1e3
            );
        }
        if lt.host_total > 0.0 {
            println!(
                "host lane on {}: {:.2} ms of offload DMA per step over the host link, \
                 {:.2} ms exposed beyond the covering compute windows",
                gpu.name(),
                lt.host_total * 1e3,
                lt.host_exposed * 1e3,
            );
        }
        if lt.tp_total > 0.0 {
            println!(
                "tp lane ×{}: {:.2} ms of all-gather/reduce-scatter per step, \
                 {:.2} ms exposed beyond the covering compute windows",
                resolved_tp,
                lt.tp_total * 1e3,
                lt.tp_exposed * 1e3,
            );
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> tempo::Result<()> {
    let dir = artifacts_dir(args);
    let index = ArtifactIndex::load_or_builtin(&dir);
    if index.is_builtin() {
        println!("artifacts (builtin sim set; no {dir}/ on disk):");
    } else {
        println!("artifacts in {dir}:");
    }
    for name in index.names() {
        let a = index.open(name)?;
        let m = &a.manifest;
        println!(
            "  {:<22} task={:<4} variant={:<10} impl={:<6} B={:<3} {} ({:.1} M params)",
            m.name,
            m.task,
            m.variant,
            m.impl_name,
            m.batch_size,
            m.config.name,
            m.param_count() as f64 / 1e6
        );
    }
    Ok(())
}
