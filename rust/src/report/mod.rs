//! Report harness: regenerate every paper table/figure as ASCII + CSV.

mod experiments;
mod table;

pub use experiments::{run_experiment, run_experiments, Experiment, ALL_EXPERIMENTS};
pub use table::Table;
