//! Report harness: regenerate every paper table/figure as ASCII + CSV.

mod experiments;
mod table;

pub use experiments::{
    run_experiment, run_experiments, tensor_rows_table, Experiment, ALL_EXPERIMENTS,
};
pub use table::Table;
