//! ASCII table printer + CSV emitter (shared by all experiment reports).

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = format!("== {} ==\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Write CSV under `bench_results/`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, rule, 2 rows
        // both value cells start at the same column
        let col = lines[3].find('1').unwrap();
        assert_eq!(lines[4].find('2').unwrap(), col);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }
}
