//! ASCII table printer + CSV/JSON emitter (shared by all experiment
//! reports and the `--json` CLI output modes).

use crate::util::Json;

/// A simple column-aligned table.
#[derive(Debug, Clone)]
pub struct Table {
    /// Table title (rendered as `== title ==`).
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Data rows (one cell per header).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// Empty table with a title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a data row (must match the header count).
    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = format!("== {} ==\n", self.title);
        let line = |cells: &[String], w: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<width$}", c, width = w[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.headers, &w));
        out.push('\n');
        out.push_str(&"-".repeat(w.iter().sum::<usize>() + 2 * (w.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &w));
            out.push('\n');
        }
        out
    }

    /// Render as CSV.
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.headers.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Machine-readable form (`{"title", "headers", "rows"}`) for the
    /// CLI `--json` modes; [`Table::from_json`] round-trips it.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::str(self.title.clone())),
            (
                "headers",
                Json::arr(self.headers.iter().map(|h| Json::str(h.clone())).collect()),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::str(c.clone())).collect()))
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a table back out of its [`Table::to_json`] form.
    pub fn from_json(v: &Json) -> crate::Result<Table> {
        let title = v.req("title")?.as_str()?.to_string();
        let headers = v
            .req("headers")?
            .as_arr()?
            .iter()
            .map(|h| Ok(h.as_str()?.to_string()))
            .collect::<crate::Result<Vec<String>>>()?;
        let rows = v
            .req("rows")?
            .as_arr()?
            .iter()
            .map(|r| {
                r.as_arr()?
                    .iter()
                    .map(|c| Ok(c.as_str()?.to_string()))
                    .collect::<crate::Result<Vec<String>>>()
            })
            .collect::<crate::Result<Vec<Vec<String>>>>()?;
        Ok(Table { title, headers, rows })
    }

    /// Write CSV under `bench_results/`.
    pub fn write_csv(&self, name: &str) -> std::io::Result<std::path::PathBuf> {
        let dir = std::path::Path::new("bench_results");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{name}.csv"));
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5); // title, header, rule, 2 rows
        // both value cells start at the same column
        let col = lines[3].find('1').unwrap();
        assert_eq!(lines[4].find('2').unwrap(), col);
    }

    #[test]
    fn json_round_trips() {
        let mut t = Table::new("demo — schedule", &["op", "live MB"]);
        t.row(vec!["attn.softmax".into(), "12.583".into()]);
        t.row(vec!["has\"quote,comma".into(), "0".into()]);
        let text = t.to_json().pretty();
        let back = Table::from_json(&crate::util::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.title, t.title);
        assert_eq!(back.headers, t.headers);
        assert_eq!(back.rows, t.rows);
        // and the re-serialized form is byte-identical
        assert_eq!(back.to_json().pretty(), text);
    }

    #[test]
    fn from_json_rejects_malformed_tables() {
        let v = crate::util::Json::parse(r#"{"title": "x", "headers": ["a"]}"#).unwrap();
        assert!(Table::from_json(&v).is_err());
        let v = crate::util::Json::parse(r#"{"title": "x", "headers": ["a"], "rows": [3]}"#).unwrap();
        assert!(Table::from_json(&v).is_err());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["has,comma".into(), "has\"quote".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }
}
