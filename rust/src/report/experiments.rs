//! The experiment registry: one entry per paper table/figure.
//!
//! `tempo experiments --id <id>` (or `--all`) prints each table and
//! writes `bench_results/<id>.csv`. Training-based experiments (fig6a,
//! fig6b) live in the coordinator and are driven by the `compare` /
//! `finetune` subcommands plus `examples/pretrain_e2e.rs`.

use crate::config::{Gpu, ModelConfig, Technique};
use crate::memmodel::{ablation_fig12, breakdown_fig9, gb_at_b15, max_batch, table2, PAPER_GB_AT_B15};
use crate::perfmodel::{throughput_at, throughput_at_max_batch};
use crate::Result;

use super::table::Table;

/// A regenerable experiment.
#[derive(Debug, Clone, Copy)]
pub struct Experiment {
    /// CLI id (`tempo experiments --id <id>`; also the CSV file name).
    pub id: &'static str,
    /// Which paper table/figure this regenerates.
    pub paper_ref: &'static str,
    /// One-line description for listings.
    pub description: &'static str,
}

/// Every table/figure in the paper's evaluation (+ motivation section).
pub const ALL_EXPERIMENTS: &[Experiment] = &[
    Experiment { id: "table1", paper_ref: "Table 1", description: "qualitative technique comparison" },
    Experiment { id: "fig1", paper_ref: "Figure 1", description: "retained-tensor inventory with rewrite annotations" },
    Experiment { id: "fig2", paper_ref: "Figure 2", description: "throughput vs batch size (motivation)" },
    Experiment { id: "fig9", paper_ref: "Figure 9 (App A)", description: "memory breakdown, BERT_BASE B=32 S=128" },
    Experiment { id: "table2", paper_ref: "Table 2", description: "max batch per GPU/seq/technique" },
    Experiment { id: "mem-at-b15", paper_ref: "§4.2", description: "total GB at B=15 S=128" },
    Experiment { id: "fig5", paper_ref: "Figure 5", description: "throughput at max batch + speedups" },
    Experiment { id: "fig7", paper_ref: "Figure 7", description: "hidden-size ablation on A100" },
    Experiment { id: "fig8", paper_ref: "Figure 8", description: "sequence-length ablation on A100" },
    Experiment { id: "other-models", paper_ref: "§4.3", description: "GPT2 / RoBERTa speedups" },
    Experiment { id: "fig12", paper_ref: "Figure 12 (App H)", description: "per-optimization memory ablation" },
    Experiment { id: "gelu-approx", paper_ref: "Fig 3a/10", description: "GELU inverse approximation quality" },
];

fn fmt_speedup(a: f64, b: f64) -> String {
    if b == 0.0 {
        return "∞".into();
    }
    format!("{:+.1}%", 100.0 * (a / b - 1.0))
}

fn exp_table1() -> Table {
    let mut t = Table::new(
        "Table 1 — technique comparison",
        &["feature", "Capuchin", "Checkmate", "ActNN", "Gist", "Tempo"],
    );
    for (feat, row) in [
        ("Layer-Specific", ["no", "no", "no", "yes", "yes"]),
        ("Transformer-Specific", ["no", "no", "no", "no", "yes"]),
        ("Lossless", ["yes", "yes", "no", "~ (1)", "~ (2)"]),
        ("Drop-In Layer Replacement", ["no", "no", "yes", "yes", "yes"]),
        ("Online", ["yes", "no", "yes", "yes", "yes"]),
    ] {
        let mut cells = vec![feat.to_string()];
        cells.extend(row.iter().map(|s| s.to_string()));
        t.row(cells);
    }
    t
}

/// Render retained-tensor rows from the graph IR as a report table
/// (shared by the `fig1` experiment and `tempo graph`).
pub fn tensor_rows_table(title: impl Into<String>, rows: Vec<crate::graph::TensorRow>) -> Table {
    let mut t = Table::new(title, &["op", "tensor", "shape", "dtype", "MB", "status"]);
    for r in rows {
        t.row(vec![
            r.op.to_string(),
            r.tensor.to_string(),
            r.shape,
            r.dtype.to_string(),
            format!("{:.3}", r.bytes as f64 / 1e6),
            r.status,
        ]);
    }
    t
}

fn exp_fig1() -> Table {
    // Fig 1: the per-layer retained-tensor inventory, from the shared
    // layer-graph IR, with Tempo's rewrites annotated tensor by tensor.
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    let opts = crate::config::OptimizationSet::full();
    tensor_rows_table(
        "Fig 1 — retained tensors, one BERT_LARGE layer @ S=512 B=1 (Tempo rewrites annotated)",
        crate::graph::tensor_table(&cfg, opts, 1),
    )
}

fn exp_fig2() -> Table {
    let mut t = Table::new(
        "Fig 2 — throughput vs batch, BERT_LARGE fine-tuning, 2080Ti",
        &["seq_len", "batch", "seqs_per_s"],
    );
    for s in [128usize, 512] {
        let cfg = ModelConfig::bert_large().with_seq_len(s);
        let maxb = max_batch(&cfg, Technique::Baseline, Gpu::Rtx2080Ti).max_batch;
        let mut b = 1;
        while b <= maxb {
            let p = throughput_at(&cfg, Technique::Baseline, Gpu::Rtx2080Ti, b);
            t.row(vec![s.to_string(), b.to_string(), format!("{:.2}", p.seqs_per_s)]);
            b = if b * 2 <= maxb || b == maxb { b * 2 } else { maxb };
        }
    }
    t
}

fn exp_fig9() -> Table {
    let mut t = Table::new(
        "Fig 9 — GPU memory breakdown, BERT_BASE fine-tune B=32 S=128",
        &["component", "GB", "share"],
    );
    let cfg = ModelConfig::bert_base().with_seq_len(128);
    for row in breakdown_fig9(&cfg, Technique::Baseline, 32) {
        t.row(vec![
            row.label.to_string(),
            format!("{:.2}", row.bytes as f64 / 1e9),
            format!("{:.1}%", 100.0 * row.share),
        ]);
    }
    t
}

fn exp_table2() -> Table {
    let mut t = Table::new(
        "Table 2 — max batch, BERT_LARGE (model vs paper)",
        &["gpu", "technique", "seq_len", "model", "paper"],
    );
    for row in table2() {
        t.row(vec![
            row.gpu.name().to_string(),
            row.technique.name().to_string(),
            row.seq_len.to_string(),
            row.model_batch.to_string(),
            row.paper_batch.to_string(),
        ]);
    }
    t
}

fn exp_mem_at_b15() -> Table {
    let mut t = Table::new(
        "§4.2 — total memory at B=15, S=128, BERT_LARGE",
        &["technique", "model GB", "paper GB"],
    );
    for (tech, paper) in PAPER_GB_AT_B15 {
        t.row(vec![
            tech.name().to_string(),
            format!("{:.2}", gb_at_b15(tech)),
            format!("{paper:.1}"),
        ]);
    }
    t
}

fn exp_fig5() -> Table {
    let mut t = Table::new(
        "Fig 5 — throughput at max batch (speedup vs best baseline)",
        &["gpu", "seq_len", "technique", "batch", "seqs_per_s", "tempo speedup"],
    );
    for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
        for s in [128usize, 512] {
            let cfg = ModelConfig::bert_large().with_seq_len(s);
            let pts: Vec<_> = Technique::all()
                .iter()
                .map(|&tech| throughput_at_max_batch(&cfg, tech, gpu))
                .collect();
            let tempo = pts[2].seqs_per_s;
            let best_baseline = pts[0].seqs_per_s.max(pts[1].seqs_per_s);
            for p in &pts {
                let note = if p.technique == Technique::Tempo {
                    fmt_speedup(tempo, best_baseline)
                } else {
                    String::new()
                };
                t.row(vec![
                    gpu.name().to_string(),
                    s.to_string(),
                    p.technique.name().to_string(),
                    p.batch.to_string(),
                    format!("{:.2}", p.seqs_per_s),
                    note,
                ]);
            }
        }
    }
    t
}

fn exp_fig7() -> Table {
    let mut t = Table::new(
        "Fig 7 — hidden-size ablation (A100), normalized throughput",
        &["config", "seq_len", "technique", "batch", "normalized", "tempo speedup"],
    );
    let widened = |cfg: ModelConfig, h: usize| {
        cfg.with_hidden(h).expect("Fig 7 hidden sizes are multiples of 64")
    };
    let configs = [
        ("BERT_LARGE H=1024", ModelConfig::bert_large()),
        ("BERT_BASE H=2048", widened(ModelConfig::bert_base(), 2048)),
        ("BERT_LARGE H=2048", widened(ModelConfig::bert_large(), 2048)),
        ("BERT_BASE H=3072", widened(ModelConfig::bert_base(), 3072)),
    ];
    for (name, base_cfg) in configs {
        for s in [128usize, 512] {
            let cfg = base_cfg.with_seq_len(s);
            let pts: Vec<_> = Technique::all()
                .iter()
                .map(|&tech| throughput_at_max_batch(&cfg, tech, Gpu::A100))
                .collect();
            let base = pts[0].seqs_per_s;
            let best_baseline = pts[0].seqs_per_s.max(pts[1].seqs_per_s);
            for p in &pts {
                let note = if p.technique == Technique::Tempo {
                    fmt_speedup(p.seqs_per_s, best_baseline)
                } else {
                    String::new()
                };
                t.row(vec![
                    name.to_string(),
                    s.to_string(),
                    p.technique.name().to_string(),
                    p.batch.to_string(),
                    format!("{:.3}", p.seqs_per_s / base),
                    note,
                ]);
            }
        }
    }
    t
}

fn exp_fig8() -> Table {
    let mut t = Table::new(
        "Fig 8 — sequence-length ablation, BERT_LARGE-12L (A100)",
        &["seq_len", "technique", "batch", "normalized", "tempo speedup"],
    );
    let cfg12 = ModelConfig::bert_large().with_layers(12);
    for s in [512usize, 1024, 1536, 2048, 2560, 3072] {
        let cfg = cfg12.with_seq_len(s);
        let pts: Vec<_> = Technique::all()
            .iter()
            .map(|&tech| throughput_at_max_batch(&cfg, tech, Gpu::A100))
            .collect();
        let base = pts[0].seqs_per_s;
        let best_baseline = pts[0].seqs_per_s.max(pts[1].seqs_per_s);
        for p in &pts {
            let note = if p.technique == Technique::Tempo {
                if best_baseline > 0.0 { fmt_speedup(p.seqs_per_s, best_baseline) } else { "only runner".into() }
            } else {
                String::new()
            };
            let norm = if base > 0.0 {
                format!("{:.3}", p.seqs_per_s / base)
            } else {
                "OOM-baseline".into()
            };
            t.row(vec![
                s.to_string(),
                p.technique.name().to_string(),
                p.batch.to_string(),
                norm,
                note,
            ]);
        }
    }
    t
}

fn exp_other_models() -> Table {
    let mut t = Table::new(
        "§4.3 — other models (paper: GPT2 +19%, RoBERTa +26% on 2080Ti; +5%/+4% on V100)",
        &["model", "gpu", "technique", "batch", "seqs_per_s", "tempo vs baseline"],
    );
    for cfg in [ModelConfig::gpt2(), ModelConfig::roberta_large()] {
        for gpu in [Gpu::Rtx2080Ti, Gpu::V100] {
            let pts: Vec<_> = Technique::all()
                .iter()
                .map(|&tech| throughput_at_max_batch(&cfg, tech, gpu))
                .collect();
            let base = pts[0].seqs_per_s;
            for p in &pts {
                let note = if p.technique == Technique::Tempo {
                    fmt_speedup(p.seqs_per_s, base)
                } else {
                    String::new()
                };
                t.row(vec![
                    cfg.name.clone(),
                    gpu.name().to_string(),
                    p.technique.name().to_string(),
                    p.batch.to_string(),
                    format!("{:.2}", p.seqs_per_s),
                    note,
                ]);
            }
        }
    }
    t
}

fn exp_fig12() -> Table {
    let mut t = Table::new(
        "Fig 12 — per-layer footprint reduction by optimization",
        &["seq_len", "optimization", "reduction share"],
    );
    let cfg = ModelConfig::bert_base();
    for row in ablation_fig12(&cfg, &[128, 256, 512, 1024, 2048, 3072]) {
        t.row(vec![
            row.seq_len.to_string(),
            row.optimization.to_string(),
            format!("{:.1}%", 100.0 * row.reduction_share),
        ]);
    }
    t
}

fn exp_gelu_approx() -> Table {
    // The kernel-side fit quality is asserted in python/tests/test_gelu.py;
    // here we document the knee points that define the piecewise scheme.
    let mut t = Table::new(
        "Fig 3a/10 — In-place GELU approximation summary",
        &["quantity", "value"],
    );
    for (k, v) in [
        ("x* (GELU minimum)", "-0.7517915246935645".to_string()),
        ("y* = GELU(x*)", "-0.16997120747990369".to_string()),
        ("mask", "int8, 1 byte/elt (paper footnote 3)".to_string()),
        ("fit variable", "u = sqrt(y - y*) (analytic across the minimum)".to_string()),
        ("segments / degree", "6 per branch / 11 (≤13 per paper)".to_string()),
        ("max |err| vs GELU'", "≤ 5.1e-4 (budget 2e-3; see pytest)".to_string()),
    ] {
        t.row(vec![k.to_string(), v]);
    }
    t
}

/// Run several experiments as independent cells on the experiment
/// engine; results come back in `ids` order regardless of completion
/// order, and a failing experiment occupies its slot with the error
/// instead of aborting the rest (table building is pure — printing and
/// CSV writing stay with the caller, serial and deterministic).
pub fn run_experiments(
    ids: &[&str],
    engine: &crate::coordinator::ExperimentEngine,
) -> Vec<(String, Result<Table>)> {
    let results = engine.run_cells(ids.len(), |i| run_experiment(ids[i]));
    ids.iter()
        .map(|id| id.to_string())
        .zip(results)
        .collect()
}

/// Run one experiment by id; returns the table (pure — no printing, no
/// file IO).
pub fn run_experiment(id: &str) -> Result<Table> {
    let table = match id {
        "table1" => exp_table1(),
        "fig1" => exp_fig1(),
        "fig2" => exp_fig2(),
        "fig9" => exp_fig9(),
        "table2" => exp_table2(),
        "mem-at-b15" => exp_mem_at_b15(),
        "fig5" => exp_fig5(),
        "fig7" => exp_fig7(),
        "fig8" => exp_fig8(),
        "other-models" => exp_other_models(),
        "fig12" => exp_fig12(),
        "gelu-approx" => exp_gelu_approx(),
        other => {
            return Err(crate::Error::Invalid(format!(
                "unknown experiment '{other}'; known: {}",
                ALL_EXPERIMENTS.iter().map(|e| e.id).collect::<Vec<_>>().join(", ")
            )))
        }
    };
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registered_experiment_runs() {
        for e in ALL_EXPERIMENTS {
            let t = run_experiment(e.id).unwrap();
            assert!(!t.rows.is_empty(), "{} produced no rows", e.id);
        }
    }

    #[test]
    fn unknown_experiment_errors() {
        assert!(run_experiment("fig99").is_err());
    }

    #[test]
    fn run_experiments_keeps_id_order_and_captures_failures() {
        let engine = crate::coordinator::ExperimentEngine::new(4);
        let ids = ["table1", "fig99", "fig2"];
        let out = run_experiments(&ids, &engine);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].0, "table1");
        assert!(out[0].1.is_ok());
        assert_eq!(out[1].0, "fig99");
        assert!(out[1].1.is_err());
        assert_eq!(out[2].0, "fig2");
        assert!(out[2].1.is_ok());
    }

    #[test]
    fn run_experiments_parallel_matches_serial() {
        let ids: Vec<&str> = ALL_EXPERIMENTS.iter().map(|e| e.id).collect();
        let serial = run_experiments(&ids, &crate::coordinator::ExperimentEngine::serial());
        let parallel = run_experiments(&ids, &crate::coordinator::ExperimentEngine::new(4));
        for ((id_s, t_s), (id_p, t_p)) in serial.iter().zip(&parallel) {
            assert_eq!(id_s, id_p);
            let (t_s, t_p) = (t_s.as_ref().unwrap(), t_p.as_ref().unwrap());
            assert_eq!(t_s.render(), t_p.render(), "{id_s} diverged across --jobs");
        }
    }

    #[test]
    fn fig5_has_12_rows() {
        let t = run_experiment("fig5").unwrap();
        assert_eq!(t.rows.len(), 12); // 2 gpus × 2 seqs × 3 techniques
    }

    #[test]
    fn table2_matches_calib_rows() {
        let t = run_experiment("table2").unwrap();
        assert_eq!(t.rows.len(), 12);
    }
}
