//! Tiny CLI argument parser (in-tree `clap` stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments, in order (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` tokens.
    pub flags: Vec<String>,
    /// Option keys that were consumed via a typed getter (for unknown-key
    /// diagnostics).
    known: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse an iterator of raw args (without argv[0]).
    ///
    /// A token starting with `--` becomes a flag unless the next token
    /// exists and does not start with `--`, in which case it is an
    /// option with that value. `--k=v` is always an option.
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Args {
        let raw: Vec<String> = raw.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether `--name` was passed as a bare flag.
    pub fn flag(&self, name: &str) -> bool {
        self.known.borrow_mut().insert(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    /// The value of option `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.known.borrow_mut().insert(name.to_string());
        self.options.get(name).map(String::as_str)
    }

    /// Option value with a default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Integer option with a default.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Invalid(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    /// Float option with a default.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Invalid(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Require an option.
    pub fn require(&self, name: &str) -> Result<String> {
        self.get(name)
            .map(str::to_string)
            .ok_or_else(|| Error::Invalid(format!("missing required --{name}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string))
    }

    #[test]
    fn mixed_styles() {
        let a = parse("train --steps 100 --lr=1e-4 --verbose --out dir pos1");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 1e-4);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("out"), Some("dir"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("--steps ten");
        assert!(a.get_usize("steps", 5).is_err());
        assert_eq!(a.get_usize("other", 7).unwrap(), 7);
        assert!(a.require("missing").is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--fast");
        assert!(a.flag("fast"));
        assert!(a.positional.is_empty());
    }
}
