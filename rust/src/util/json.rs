//! Minimal JSON: full parser + pretty writer.
//!
//! Covers everything `manifest.json` / `index.json` / the report CSV/JSON
//! emitters need: objects, arrays, strings (with escapes), numbers,
//! booleans, null. Numbers are kept as f64 (the manifests only carry
//! shapes/counts well inside 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (kept as f64; manifests stay inside 2⁵³).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys, so serialization is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array from values.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// Number value.
    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    /// String value.
    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    // ---- accessors ---------------------------------------------------------

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Required object field.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Parse(format!("missing field '{key}'")))
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => Err(Error::Parse(format!("expected string, got {other:?}"))),
        }
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => Err(Error::Parse(format!("expected number, got {other:?}"))),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(Error::Parse(format!("expected non-negative integer, got {n}")));
        }
        Ok(n as usize)
    }

    /// The value as a boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => Err(Error::Parse(format!("expected bool, got {other:?}"))),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => Err(Error::Parse(format!("expected array, got {other:?}"))),
        }
    }

    /// Array of usize (shape lists).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    // ---- parsing -----------------------------------------------------------

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Parse(format!("trailing garbage at byte {}", p.pos)));
        }
        Ok(v)
    }

    // ---- writing -----------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization (2-space indent).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| Error::Parse("unexpected end of input".into()))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            return Err(Error::Parse(format!(
                "expected '{}' at byte {}, found '{}'",
                b as char, self.pos, self.bytes[self.pos] as char
            )));
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(Error::Parse(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => {
                    return Err(Error::Parse(format!(
                        "expected ',' or '}}' at byte {}, found '{}'",
                        self.pos, other as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(Error::Parse(format!(
                        "expected ',' or ']' at byte {}, found '{}'",
                        self.pos, other as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(Error::Parse("truncated \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Parse("bad \\u escape".into()))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => {
                            return Err(Error::Parse(format!(
                                "bad escape '\\{}'",
                                other as char
                            )))
                        }
                    }
                }
                b => {
                    // Collect the full UTF-8 sequence starting at b.
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(Error::Parse("truncated utf-8".into()));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| Error::Parse("invalid utf-8".into()))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Parse(format!("bad number '{text}' at byte {start}")))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{"name": "x", "n": 3, "shape": [2, 64], "ok": true,
                      "files": {"init": "a.txt"}, "none": null, "f": -1.5e-3}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.req("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(v.req("n").unwrap().as_usize().unwrap(), 3);
        assert_eq!(v.req("shape").unwrap().as_usize_vec().unwrap(), vec![2, 64]);
        assert!(v.req("ok").unwrap().as_bool().unwrap());
        assert_eq!(
            v.req("files").unwrap().req("init").unwrap().as_str().unwrap(),
            "a.txt"
        );
        assert_eq!(v.req("none").unwrap(), &Json::Null);
        assert!((v.req("f").unwrap().as_f64().unwrap() + 0.0015).abs() < 1e-12);
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("a", Json::arr(vec![Json::num(1.0), Json::num(2.5), Json::Null])),
            ("s", Json::str("he\"llo\nworld")),
            ("b", Json::Bool(false)),
        ]);
        let text = v.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back);
        let compact = v.to_string();
        assert_eq!(Json::parse(&compact).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }

    #[test]
    fn errors_are_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
        assert!(Json::parse("12abc").is_err());
    }

    #[test]
    fn integers_print_clean() {
        assert_eq!(Json::num(64.0).to_string(), "64");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }
}
