//! In-tree micro-benchmark harness (criterion stand-in).
//!
//! Warms up, then runs timed iterations until both a minimum iteration
//! count and a minimum wall budget are met; reports mean/p50/p95/stddev.
//! Used by the `benches/*.rs` targets (harness = false).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::tensor::stats;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case name (e.g. `schedule/lower-cold/bert-large-s512`).
    pub name: String,
    /// Timed iterations executed.
    pub iters: usize,
    /// Mean iteration time.
    pub mean: Duration,
    /// Median iteration time.
    pub p50: Duration,
    /// 95th-percentile iteration time.
    pub p95: Duration,
    /// Iteration-time standard deviation.
    pub stddev: Duration,
}

impl BenchResult {
    /// Mean iterations/second.
    pub fn throughput(&self) -> f64 {
        if self.mean.as_secs_f64() > 0.0 {
            1.0 / self.mean.as_secs_f64()
        } else {
            f64::INFINITY
        }
    }
}

/// Bench driver with configurable budgets.
pub struct BenchHarness {
    /// Untimed warmup iterations per case.
    pub warmup_iters: usize,
    /// Minimum timed iterations per case.
    pub min_iters: usize,
    /// Hard cap on timed iterations per case.
    pub max_iters: usize,
    /// Minimum wall-clock budget per case.
    pub min_time: Duration,
    results: Vec<BenchResult>,
    annotations: BTreeMap<String, Vec<(String, f64)>>,
}

impl Default for BenchHarness {
    fn default() -> Self {
        BenchHarness {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            min_time: Duration::from_millis(300),
            results: Vec::new(),
            annotations: BTreeMap::new(),
        }
    }
}

impl BenchHarness {
    /// Default budgets (3 warmup, ≥10 iters, ≥300 ms per case).
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick harness for expensive cases (e2e training steps).
    pub fn heavy() -> Self {
        BenchHarness {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            min_time: Duration::from_millis(500),
            ..Self::default()
        }
    }

    /// Attach an extra numeric field to the named case's JSON row —
    /// e.g. cache hit/miss counters next to the timing they explain.
    /// Rows keep their `name`/`mean_s` core (CI's parser requires
    /// those); [`write_csv`](Self::write_csv) output is unchanged.
    /// Annotating a name no [`bench`](Self::bench) call recorded is
    /// silently never emitted.
    pub fn annotate(&mut self, name: &str, key: &str, value: f64) {
        self.annotations.entry(name.to_string()).or_default().push((key.to_string(), value));
    }

    /// Time `f` and record under `name`. Returns the result.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (start.elapsed() < self.min_time && samples.len() < self.max_iters)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let mut sorted = samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
            sorted[idx]
        };
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            mean: Duration::from_secs_f64(stats::mean(&samples)),
            p50: Duration::from_secs_f64(pct(0.50)),
            p95: Duration::from_secs_f64(pct(0.95)),
            stddev: Duration::from_secs_f64(stats::stddev(&samples)),
        };
        println!(
            "bench {:<42} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
            res.name, res.mean, res.p50, res.p95, res.iters
        );
        self.results.push(res.clone());
        res
    }

    /// All recorded case results, in run order.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Write results as a JSON array (machine-readable trajectory
    /// artifact, e.g. CI's `BENCH_graph.json`).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json::Json;
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let rows = self
            .results
            .iter()
            .map(|r| {
                let mut pairs = vec![
                    ("name", Json::str(r.name.clone())),
                    ("iters", Json::num(r.iters as f64)),
                    ("mean_s", Json::num(r.mean.as_secs_f64())),
                    ("p50_s", Json::num(r.p50.as_secs_f64())),
                    ("p95_s", Json::num(r.p95.as_secs_f64())),
                    ("stddev_s", Json::num(r.stddev.as_secs_f64())),
                ];
                if let Some(extras) = self.annotations.get(&r.name) {
                    for (k, v) in extras {
                        pairs.push((k.as_str(), Json::num(*v)));
                    }
                }
                Json::obj(pairs)
            })
            .collect();
        std::fs::write(path, Json::arr(rows).pretty())
    }

    /// Write results as CSV (`bench_results/<file>`).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::from("name,iters,mean_s,p50_s,p95_s,stddev_s\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{:.9},{:.9},{:.9},{:.9}\n",
                r.name,
                r.iters,
                r.mean.as_secs_f64(),
                r.p50.as_secs_f64(),
                r.p95.as_secs_f64(),
                r.stddev.as_secs_f64()
            ));
        }
        std::fs::write(path, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders_percentiles() {
        let mut h = BenchHarness {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 20,
            min_time: Duration::from_millis(1),
            ..BenchHarness::default()
        };
        let mut x = 0u64;
        let r = h.bench("spin", || {
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
        });
        assert!(r.iters >= 5);
        assert!(r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
        assert_eq!(h.results().len(), 1);
    }

    #[test]
    fn json_emits_parseable_rows() {
        let dir = crate::util::TempDir::new().unwrap();
        let mut h = BenchHarness {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 2,
            min_time: Duration::ZERO,
            ..BenchHarness::default()
        };
        h.bench("case", || {});
        let p = dir.file("out.json");
        h.write_json(p.to_str().unwrap()).unwrap();
        let parsed = crate::util::Json::parse(&std::fs::read_to_string(p).unwrap()).unwrap();
        let rows = parsed.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].req("name").unwrap().as_str().unwrap(), "case");
        assert!(rows[0].req("mean_s").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn annotations_ride_on_their_named_row_only() {
        let dir = crate::util::TempDir::new().unwrap();
        let mut h = BenchHarness {
            warmup_iters: 0,
            min_iters: 1,
            max_iters: 1,
            min_time: Duration::ZERO,
            ..BenchHarness::default()
        };
        h.bench("plain", || {});
        h.bench("annotated", || {});
        h.annotate("annotated", "cache_hits", 7.0);
        h.annotate("annotated", "cache_misses", 2.0);
        h.annotate("never-ran", "ghost", 1.0);
        let p = dir.file("out.json");
        h.write_json(p.to_str().unwrap()).unwrap();
        let parsed = crate::util::Json::parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        let rows = parsed.as_arr().unwrap();
        assert_eq!(rows.len(), 2, "annotating a name that never ran adds no row");
        assert!(rows[0].get("cache_hits").is_none(), "extras stay on their named row");
        assert_eq!(rows[1].req("cache_hits").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(rows[1].req("cache_misses").unwrap().as_f64().unwrap(), 2.0);
        for row in rows {
            // the CI parser's contract: every row keeps name + mean_s
            assert!(row.req("name").is_ok() && row.req("mean_s").is_ok());
        }

        // CSV output ignores annotations entirely
        let c = dir.file("out.csv");
        h.write_csv(c.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(c).unwrap();
        assert!(!text.contains("cache_hits"));
    }

    #[test]
    fn csv_emits_rows() {
        let dir = crate::util::TempDir::new().unwrap();
        let mut h = BenchHarness {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 2,
            min_time: Duration::ZERO,
            ..BenchHarness::default()
        };
        h.bench("a", || {});
        let p = dir.file("out.csv");
        h.write_csv(p.to_str().unwrap()).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.starts_with("name,iters"));
        assert!(text.lines().count() == 2);
    }
}
