//! Self-cleaning temp directories for tests (in-tree `tempfile` stand-in).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique directory under the system temp dir, removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create `tempo-<pid>-<n>` under `std::env::temp_dir()`.
    pub fn new() -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "tempo-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.subsec_nanos())
                .unwrap_or(0)
        ));
        std::fs::create_dir_all(&path)?;
        Ok(TempDir { path })
    }

    /// The directory's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Join a file name onto the temp dir.
    pub fn file(&self, name: &str) -> PathBuf {
        self.path.join(name)
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let keep;
        {
            let d = TempDir::new().unwrap();
            keep = d.path().to_path_buf();
            std::fs::write(d.file("x.txt"), "hi").unwrap();
            assert!(keep.exists());
        }
        assert!(!keep.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new().unwrap();
        let b = TempDir::new().unwrap();
        assert_ne!(a.path(), b.path());
    }
}
