//! In-tree utility substrates (the build is fully offline, so JSON, CLI
//! parsing, the bench harness, temp dirs and property testing are all
//! implemented here rather than pulled from crates.io).

pub mod bench;
pub mod cli;
pub mod json;
pub mod temp;

pub use bench::{BenchHarness, BenchResult};
pub use cli::Args;
pub use json::Json;
pub use temp::TempDir;
