//! Quickstart: the whole stack in ~40 lines, zero setup.
//!
//! Opens the artifact index (the builtin sim set when `make artifacts`
//! hasn't run), initializes parameters on the deterministic sim
//! backend, and takes a few optimizer steps on the synthetic corpus.
//! With `--features pjrt` + artifacts on disk, pass `--backend pjrt`
//! to the `tempo` binary instead for the real PJRT path.
//!
//! Run: `cargo run --release --example quickstart`

use tempo::config::TrainingConfig;
use tempo::coordinator::{Trainer, TrainerOptions};
use tempo::runtime::{ArtifactIndex, Backend, SimBackend};

fn main() -> tempo::Result<()> {
    let index = ArtifactIndex::load_or_builtin("artifacts");
    let backend = SimBackend::new();
    println!("backend: {}", backend.name());
    println!("available artifacts: {:?}", index.names());

    let cfg = TrainingConfig {
        artifact: "bert_tiny_tempo".into(),
        steps: 20,
        warmup_steps: 5,
        peak_lr: 1e-3,
        seed: 42,
        eval_every: 10,
        log_every: 5,
    };
    let artifact = index.open(&cfg.artifact)?;
    println!(
        "training {} — {} ({} layers, H={}, S={}, B={})",
        artifact.manifest.name,
        artifact.manifest.config.name,
        artifact.manifest.config.layers,
        artifact.manifest.config.hidden,
        artifact.manifest.config.seq_len,
        artifact.manifest.batch_size,
    );

    let mut trainer = Trainer::new(&backend, artifact, cfg, TrainerOptions { verbose: true, ..Default::default() })?;
    trainer.run()?;

    let m = trainer.metrics();
    println!(
        "\nfirst loss {:.4} → last loss {:.4} @ {:.1} seq/s (roofline-modeled)",
        m.records().first().map(|r| r.loss).unwrap_or(f64::NAN),
        m.last_loss().unwrap_or(f64::NAN),
        m.throughput()
    );
    Ok(())
}
