//! Quickstart: the whole three-layer stack in ~40 lines.
//!
//! Loads the AOT-compiled Tempo BERT-tiny training step (lowered once by
//! `make artifacts`; python never runs here), initializes parameters on
//! the PJRT CPU client, and takes a few optimizer steps on the synthetic
//! corpus.
//!
//! Run: `cargo run --release --example quickstart`

use tempo::config::TrainingConfig;
use tempo::coordinator::{Trainer, TrainerOptions};
use tempo::runtime::{ArtifactIndex, Runtime};

fn main() -> anyhow::Result<()> {
    let index = ArtifactIndex::load("artifacts")?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    println!("available artifacts: {:?}", index.names());

    let cfg = TrainingConfig {
        artifact: "bert_tiny_tempo".into(),
        steps: 20,
        warmup_steps: 5,
        peak_lr: 1e-3,
        seed: 42,
        eval_every: 10,
        log_every: 5,
    };
    let artifact = index.open(&cfg.artifact)?;
    println!(
        "training {} — {} ({} layers, H={}, S={}, B={})",
        artifact.manifest.name,
        artifact.manifest.config.name,
        artifact.manifest.config.layers,
        artifact.manifest.config.hidden,
        artifact.manifest.config.seq_len,
        artifact.manifest.batch_size,
    );

    let mut trainer = Trainer::new(&rt, artifact, cfg, TrainerOptions { verbose: true, ..Default::default() })?;
    trainer.run()?;

    let m = trainer.metrics();
    println!(
        "\nfirst loss {:.4} → last loss {:.4} @ {:.1} seq/s",
        m.records().first().map(|r| r.loss).unwrap_or(f64::NAN),
        m.last_loss().unwrap_or(f64::NAN),
        m.throughput()
    );
    Ok(())
}
