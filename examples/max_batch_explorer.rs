//! Interactive-ish capacity explorer: sweep models × GPUs × sequence
//! lengths and print the max-batch table plus the Tempo memory win —
//! the tool a practitioner would use before launching a training job.
//!
//! Run: `cargo run --release --example max_batch_explorer [-- --model bert-large]`

use tempo::config::{Gpu, ModelConfig, Technique};
use tempo::memmodel::{max_batch, ModelFootprint};
use tempo::report::Table;
use tempo::util::Args;

fn main() -> tempo::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let models: Vec<ModelConfig> = match args.get("model") {
        Some(name) => vec![ModelConfig::preset(name)
            .ok_or_else(|| tempo::Error::Invalid(format!("unknown preset {name}")))?],
        None => vec![
            ModelConfig::bert_base(),
            ModelConfig::bert_large(),
            ModelConfig::gpt2(),
            ModelConfig::roberta_large(),
        ],
    };

    let mut t = Table::new(
        "max batch per (model, GPU, S, technique) — analytical capacity model",
        &["model", "gpu", "seq", "Baseline", "Checkpoint", "Tempo", "Tempo vs Baseline"],
    );
    for cfg in &models {
        for gpu in Gpu::all() {
            for s in [128usize, 512] {
                let c = cfg.with_seq_len(s);
                let b: Vec<usize> = Technique::all()
                    .iter()
                    .map(|&tech| max_batch(&c, tech, gpu).max_batch)
                    .collect();
                let ratio = if b[0] > 0 {
                    format!("{:.1}×", b[2] as f64 / b[0] as f64)
                } else if b[2] > 0 {
                    "fits (baseline OOM)".into()
                } else {
                    "—".into()
                };
                t.row(vec![
                    cfg.name.clone(),
                    gpu.name().into(),
                    s.to_string(),
                    b[0].to_string(),
                    b[1].to_string(),
                    b[2].to_string(),
                    ratio,
                ]);
            }
        }
    }
    println!("{}", t.render());

    // per-component breakdown for one interesting point
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    println!("breakdown: bert-large S=512 B=2 (2080Ti scale)");
    for tech in Technique::all() {
        let bd = ModelFootprint::new(cfg.clone(), tech).breakdown(2);
        println!(
            "  {:<11} total {:>6.2} GB  (acts {:>5.2} GB, states {:>5.2} GB, {} {:>5.2} GB)",
            tech.name(),
            bd.total() as f64 / 1e9,
            bd.activations() as f64 / 1e9,
            (bd.params + bd.grads + bd.optimizer) as f64 / 1e9,
            bd.transient_label,
            bd.transient as f64 / 1e9,
        );
    }
    t.write_csv("max_batch_explorer")?;
    println!("CSV → bench_results/max_batch_explorer.csv");
    Ok(())
}
