//! End-to-end validation driver (DESIGN.md: the Fig 6a analogue).
//!
//! Trains BERT-mini for a few hundred steps on the synthetic
//! Zipf+Markov corpus three times:
//!
//!   1. Baseline artifact, data seed A
//!   2. Tempo artifact,    data seed A  (identical data + dropout masks)
//!   3. Baseline artifact, data seed B  (the run-to-run noise yardstick)
//!
//! The paper's Fig 6a claim — Tempo's curve is indistinguishable from
//! the Baseline's — is checked as: |tempo − baseline| endpoint gap
//! within the noise yardstick |baseline(A) − baseline(B)| (plus a small
//! margin), and both curves must actually learn. On the sim backend the
//! variant gap is exactly zero by construction; under `--features pjrt`
//! with artifacts present the same driver exercises the real runtime,
//! where per-step Tempo gradients match autodiff to ~1e-5 and the tiny
//! GELU-approximation differences amplify chaotically like data-order
//! noise.
//!
//! The three runs are independent cells on the concurrent experiment
//! engine (`--jobs N`, default one worker per core): results come back
//! in grid order, so the report is bit-identical for every `--jobs`.
//!
//! Run: `cargo run --release --example pretrain_e2e [-- --steps N --scale mini|tiny --jobs N]`

use tempo::config::TrainingConfig;
use tempo::coordinator::{ExperimentEngine, Trainer, TrainerOptions};
use tempo::runtime::{ArtifactIndex, Backend, SimBackend};
use tempo::util::Args;
use tempo::{Error, Result};

fn run_one<B: Backend>(
    backend: &B,
    index: &ArtifactIndex,
    artifact: &str,
    steps: usize,
    seed: u64,
    verbose: bool,
) -> Result<(Vec<f64>, f64)> {
    let cfg = TrainingConfig {
        artifact: artifact.into(),
        steps,
        warmup_steps: steps / 10,
        peak_lr: 1e-3,
        seed,
        eval_every: 0,
        log_every: (steps / 8).max(1),
    };
    let mut trainer = Trainer::new(
        backend,
        index.open(artifact)?,
        cfg,
        TrainerOptions { verbose, ..Default::default() },
    )?;
    trainer.run()?;
    let losses: Vec<f64> = trainer.metrics().records().iter().map(|r| r.loss).collect();
    Ok((losses, trainer.metrics().throughput()))
}

fn endpoint(losses: &[f64], window: usize) -> f64 {
    let n = losses.len();
    let w = window.min(n).max(1);
    losses[n - w..].iter().sum::<f64>() / w as f64
}

fn ensure(cond: bool, msg: String) -> Result<()> {
    if cond {
        Ok(())
    } else {
        Err(Error::Invalid(msg))
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let scale = args.get_or("scale", "mini");
    let steps = args.get_usize("steps", if scale == "mini" { 200 } else { 300 })?;
    let (baseline, tempo_name) = match scale.as_str() {
        "mini" => ("bert_mini_baseline", "bert_mini_tempo"),
        "tiny" => ("bert_tiny_baseline", "bert_tiny_tempo"),
        other => return Err(Error::Invalid(format!("unknown --scale {other} (mini|tiny)"))),
    };

    let index = ArtifactIndex::load_or_builtin("artifacts");
    let backend = SimBackend::new();
    // Same --jobs semantics as the tempo CLI: default/`auto`/`0` = one
    // worker per core.
    let engine = match args.get("jobs") {
        None | Some("auto") | Some("0") => ExperimentEngine::auto(),
        Some(v) => ExperimentEngine::new(v.parse().map_err(|_| {
            Error::Invalid(format!("--jobs expects an integer or 'auto', got '{v}'"))
        })?),
    };

    println!(
        "=== pretrain_e2e ({}): {baseline} vs {tempo_name}, {steps} steps, {} worker(s) ===",
        backend.name(),
        engine.jobs()
    );
    // Three independent cells; verbose per-step lines only when serial
    // (they would interleave across workers).
    let grid: [(&str, u64); 3] = [(baseline, 42), (tempo_name, 42), (baseline, 43)];
    let verbose = engine.jobs() == 1;
    let t0 = std::time::Instant::now();
    let mut cells = engine.run_cells(grid.len(), |i| {
        let (artifact, seed) = grid[i];
        run_one(&backend, &index, artifact, steps, seed, verbose)
    });
    let wall = t0.elapsed();
    let (base_b, _) = cells.pop().unwrap()?;
    let (tempo_a, thr_tempo) = cells.pop().unwrap()?;
    let (base_a, thr_base) = cells.pop().unwrap()?;

    std::fs::create_dir_all("bench_results")?;
    let mut csv = String::from("step,baseline_seedA,tempo_seedA,baseline_seedB\n");
    for i in 0..steps {
        csv.push_str(&format!(
            "{},{:.6},{:.6},{:.6}\n",
            i, base_a[i], tempo_a[i], base_b[i]
        ));
    }
    let out = format!("bench_results/pretrain_e2e_{scale}.csv");
    std::fs::write(&out, &csv)?;

    let w = (steps / 5).max(5);
    let (eb, et, en) = (endpoint(&base_a, w), endpoint(&tempo_a, w), endpoint(&base_b, w));
    let first = base_a.first().copied().unwrap_or(f64::NAN);
    let tempo_gap = (et - eb).abs() / eb;
    let noise_gap = (en - eb).abs() / eb;

    println!("\n=== results ===");
    println!("start loss        : {first:.4}");
    println!("baseline endpoint : {eb:.4}  ({thr_base:.1} seq/s)");
    println!("tempo endpoint    : {et:.4}  ({thr_tempo:.1} seq/s)");
    println!("noise yardstick   : {en:.4}  (baseline, different data seed)");
    println!(
        "tempo-vs-baseline gap {:.2}% | run-to-run noise {:.2}% (paper endpoint gap: ≤0.5% at 7k+ steps)",
        100.0 * tempo_gap,
        100.0 * noise_gap
    );
    println!("wall time: {wall:.1?} for 3×{steps} steps");
    println!("curves → {out}");

    ensure(eb < first - 0.5, format!("baseline did not learn: {eb:.3} vs start {first:.3}"))?;
    ensure(et < first - 0.5, format!("tempo did not learn: {et:.3} vs start {first:.3}"))?;
    ensure(
        tempo_gap <= (2.0 * noise_gap).max(0.03),
        format!(
            "tempo gap {:.2}% exceeds noise envelope {:.2}%",
            100.0 * tempo_gap,
            100.0 * noise_gap
        ),
    )?;
    println!("PASS: both curves learn; Tempo's endpoint sits inside the run-to-run noise envelope");
    Ok(())
}
