//! Auto-Tempo (§5.2) demo: the coarse profile-then-apply pass and the
//! fine-grained minimal-subset search, across a scenario matrix.
//! Purely analytical — needs no artifacts and no backend.
//!
//! Run: `cargo run --release --example autotempo_demo`

use tempo::autotempo::{coarse_pass, fine_search};
use tempo::config::{Gpu, ModelConfig};

fn main() {
    println!("=== coarse pass (apply-everywhere vs leave-alone) ===");
    let scenarios = [
        ("bert-large S=512 on 2080Ti (memory-starved)", ModelConfig::bert_large().with_seq_len(512), Gpu::Rtx2080Ti),
        ("bert-large S=128 on A100 (memory-rich)", ModelConfig::bert_large().with_seq_len(128), Gpu::A100),
        ("bert-tiny on A100 (trivially fits)", ModelConfig::bert_tiny(), Gpu::A100),
        ("gpt2 S=512 on 2080Ti", ModelConfig::gpt2(), Gpu::Rtx2080Ti),
    ];
    for (label, cfg, gpu) in &scenarios {
        let d = coarse_pass(cfg, *gpu);
        println!("\n{label}");
        println!("  decision : tempo on {}/{} layers", d.plan.applied_layers(), cfg.layers);
        println!("  rationale: {}", d.rationale);
        println!("  outcome  : batch {}, {:.2} seq/s", d.max_batch, d.throughput);
    }

    println!("\n=== fine-grained search (smallest sufficient layer set) ===");
    let cfg = ModelConfig::bert_large().with_seq_len(512);
    for target in [2usize, 3, 4, 6] {
        let d = fine_search(&cfg, Gpu::Rtx2080Ti, target);
        println!(
            "target batch {target}: tempo on {:>2}/{} layers → max batch {:>2}   ({})",
            d.plan.applied_layers(),
            cfg.layers,
            d.max_batch,
            d.rationale
        );
    }
}
