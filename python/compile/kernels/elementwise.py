"""Generic in-place elementwise layers (paper §5.1 / Appendix E.5).

The paper generalizes In-place GELU to *any* elementwise activation
``y = f(x)`` whose output is retained by the next layer anyway:

1. if ``f`` is bijective, recover ``x = f⁻¹(y)`` — no extra storage;
2. otherwise split the domain at the extrema, store a small indicator
   ``m`` of the branch, and recover ``x = g_m(y)`` per branch;
3. approximate ``g`` (or directly ``f' ∘ g``, Eq. 2) with piecewise
   polynomials when no closed form exists;
4. fold the computation of ``m`` into the forward kernel and the
   composite ``f' ∘ g`` into the backward kernel.

This module is the *factory* form of that recipe: given ``f`` (as a
float→float callable usable on numpy arrays) and its derivative, it
finds the interior extrema numerically, fits per-branch polynomials in
the √-stretched variable (analytic across each extremum — the same
trick gelu.py uses), and returns a ``jax.custom_vjp`` layer that stores
only ``(y, branch_id:int8)``.

Instantiated below for:
* ``inplace_silu`` — SiLU/Swish, one interior minimum (≈ -1.2784),
  structurally identical to GELU;
* ``inplace_gelu_generic`` — GELU via the generic path (cross-checked
  against the hand-tuned kernels/gelu.py in tests).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------
# numerics: extrema + per-branch fits (float64 numpy, build time only)
# --------------------------------------------------------------------------


def _find_extrema(df, lo: float, hi: float, n: int = 200001) -> list:
    """Interior sign changes of f' located by bisection."""
    xs = np.linspace(lo, hi, n)
    ds = df(xs)
    roots = []
    for i in range(n - 1):
        if ds[i] == 0.0:
            roots.append(float(xs[i]))
        elif ds[i] * ds[i + 1] < 0:
            a, b = xs[i], xs[i + 1]
            for _ in range(100):
                mid = 0.5 * (a + b)
                if df(np.asarray(mid)) * df(np.asarray(a)) <= 0:
                    b = mid
                else:
                    a = mid
            roots.append(0.5 * (a + b))
    return roots


@dataclass(frozen=True)
class Branch:
    """One monotone piece of f: polynomials in u = sqrt(|y - y_anchor|)."""

    x_lo: float
    x_hi: float
    y_anchor: float  # f at the extremum bounding this branch
    sign: float  # sign of (y - anchor) on this branch
    bounds: tuple  # segment right-edges in u
    coeffs: tuple  # [n_seg][degree+1], Horner order
    degree: int


@dataclass(frozen=True)
class InplaceSpec:
    """Everything the fwd/bwd kernels need, baked as constants."""

    name: str
    extrema: tuple  # interior extrema x*₁ < x*₂ < …
    branches: tuple  # len(extrema) + 1 Branch objects
    max_fit_err: float


def build_spec(name: str, f, df, lo: float = -10.0, hi: float = 10.0,
               degree: int = 11, n_seg: int = 6) -> InplaceSpec:
    """Run the §5.1 recipe for one activation; deterministic, <100 ms."""
    extrema = _find_extrema(df, lo, hi)
    edges = [lo] + list(extrema) + [hi]
    branches = []
    max_err = 0.0
    for b in range(len(edges) - 1):
        x_lo, x_hi = edges[b], edges[b + 1]
        # anchor at the bounding extremum (or the far edge for the outermost
        # branches, where f is monotone away from any extremum)
        anchor_x = x_hi if b == 0 else x_lo
        y_anchor = float(f(np.asarray(anchor_x)))
        xs = np.linspace(x_lo, x_hi, 20001)
        ys = f(xs)
        us = np.sqrt(np.maximum(np.abs(ys - y_anchor), 0.0))
        sign = 1.0 if float(np.mean(ys - y_anchor)) >= 0 else -1.0
        gs = df(xs)
        u_max = float(us.max())
        seg_edges = u_max * (np.linspace(0, 1, n_seg + 1) ** 1.3)
        bounds, coeffs = [], []
        for s in range(n_seg):
            sel = (us >= seg_edges[s]) & (us <= seg_edges[s + 1])
            if sel.sum() < degree + 2:
                sel = (us >= seg_edges[s] - 1e-6) & (us <= seg_edges[s + 1] + 1e-6)
            c = np.polyfit(us[sel] - seg_edges[s], gs[sel], degree)
            err = float(np.abs(np.polyval(c, us[sel] - seg_edges[s]) - gs[sel]).max())
            max_err = max(max_err, err)
            bounds.append(float(seg_edges[s + 1]))
            coeffs.append(tuple(float(v) for v in c))
        branches.append(Branch(
            x_lo=x_lo, x_hi=x_hi, y_anchor=y_anchor, sign=sign,
            bounds=tuple(bounds), coeffs=tuple(coeffs), degree=degree,
        ))
    return InplaceSpec(name=name, extrema=tuple(extrema),
                       branches=tuple(branches), max_fit_err=max_err)


# --------------------------------------------------------------------------
# jnp evaluation (same gather-free one-hot contraction as gelu.py)
# --------------------------------------------------------------------------


def _eval_branch(br: Branch, y):
    u = jnp.sqrt(jnp.maximum(br.sign * (y - br.y_anchor), 0.0))
    inner = jnp.asarray(br.bounds[:-1], jnp.float32)
    lefts = jnp.asarray((0.0,) + br.bounds[:-1], jnp.float32)
    table = jnp.asarray(br.coeffs, jnp.float32)
    n_seg = table.shape[0]
    seg = jnp.sum((u[..., None] > inner).astype(jnp.float32), axis=-1)
    onehot = (seg[..., None] == jnp.arange(n_seg, dtype=jnp.float32)).astype(jnp.float32)
    c = jnp.einsum("...s,sk->...k", onehot, table)
    t = u - jnp.einsum("...s,s->...", onehot, lefts)
    acc = c[..., 0]
    for k in range(1, br.degree + 1):
        acc = acc * t + c[..., k]
    return acc


def grad_from_output(spec: InplaceSpec, y, m):
    """f'(f⁻¹(y)) selected by the stored branch indicator (f32 internal)."""
    out_dt = y.dtype
    y = y.astype(jnp.float32)
    vals = [_eval_branch(br, y) for br in spec.branches]
    acc = vals[0]
    for i in range(1, len(vals)):
        acc = jnp.where(m >= i, vals[i], acc)
    return acc.astype(out_dt)


def branch_indicator(spec: InplaceSpec, x):
    """m = index of the branch x falls in (int8, the paper's mask)."""
    m = jnp.zeros(x.shape, jnp.int8)
    for i, xstar in enumerate(spec.extrema):
        m = jnp.where(x >= jnp.asarray(xstar, x.dtype), jnp.int8(i + 1), m)
    return m


def make_inplace_layer(spec: InplaceSpec, f_jnp):
    """Return a custom_vjp layer storing only (y, m) for backward."""

    @jax.custom_vjp
    def layer(x):
        return f_jnp(x)

    def fwd(x):
        y = f_jnp(x)
        return y, (y, branch_indicator(spec, x))

    def bwd(res, dy):
        y, m = res
        return (dy * grad_from_output(spec, y, m),)

    layer.defvjp(fwd, bwd)
    return layer


# --------------------------------------------------------------------------
# instances
# --------------------------------------------------------------------------


def _sigmoid64(x):
    return 1.0 / (1.0 + np.exp(-x))


def _silu64(x):
    return x * _sigmoid64(x)


def _dsilu64(x):
    s = _sigmoid64(x)
    return s * (1.0 + x * (1.0 - s))


def silu_jnp(x):
    out_dt = x.dtype
    x = x.astype(jnp.float32)
    return (x * jax.nn.sigmoid(x)).astype(out_dt)


SILU_SPEC = build_spec("silu", _silu64, _dsilu64)
inplace_silu = make_inplace_layer(SILU_SPEC, silu_jnp)


def _gelu64(x):
    from math import erf

    v = np.vectorize(lambda t: t * 0.5 * (1.0 + erf(t / np.sqrt(2.0))))
    return v(x)


def _dgelu64(x):
    from math import erf

    pdf = lambda t: np.exp(-0.5 * t * t) / np.sqrt(2 * np.pi)  # noqa: E731
    cdf = lambda t: 0.5 * (1.0 + erf(t / np.sqrt(2.0)))  # noqa: E731
    v = np.vectorize(lambda t: cdf(t) + t * pdf(t))
    return v(x)


def gelu_jnp(x):
    from . import ref

    return ref.gelu(x)


GELU_SPEC = build_spec("gelu", _gelu64, _dgelu64)
inplace_gelu_generic = make_inplace_layer(GELU_SPEC, gelu_jnp)
