"""Memory-efficient softmax (Tempo §3.4 engineering optimization).

PyTorch's softmax retains both input and output for backward; only the
output is necessary:  dx = (dy - Σ dy·y) · y  along the softmax axis.
For the attention scores this discards an O(B·A·S²) feature map.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 128


def _rows(x):
    return x.reshape(x.size // x.shape[-1], x.shape[-1])


def _pad_rows(x2, block):
    n = x2.shape[0]
    pad = (-n) % block
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, x2.shape[1]), x2.dtype)], axis=0)
    return x2, n


def softmax_fwd_jnp(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_bwd_jnp(dy, y):
    s = jnp.sum(dy * y, axis=-1, keepdims=True)
    return (dy - s) * y


def softmax_fwd_pallas(x, block_rows: int = _BLOCK_ROWS):
    orig = x.shape
    x2, n = _pad_rows(_rows(x), block_rows)
    rows, cols = x2.shape

    def kernel(x_ref, y_ref):
        xv = x_ref[...]
        m = jnp.max(xv, axis=-1, keepdims=True)
        e = jnp.exp(xv - m)
        y_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)

    y2 = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=True,
    )(x2)
    return y2[:n].reshape(orig)


def softmax_bwd_pallas(dy, y, block_rows: int = _BLOCK_ROWS):
    orig = y.shape
    dy2, n = _pad_rows(_rows(dy), block_rows)
    y2, _ = _pad_rows(_rows(y), block_rows)
    rows, cols = y2.shape

    def kernel(dy_ref, y_ref, dx_ref):
        dyv, yv = dy_ref[...], y_ref[...]
        s = jnp.sum(dyv * yv, axis=-1, keepdims=True)
        dx_ref[...] = (dyv - s) * yv

    dx2 = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), y.dtype),
        interpret=True,
    )(dy2, y2)
    return dx2[:n].reshape(orig)
