"""In-place LayerNorm (Tempo §3.2, Appendix D).

Forward: one fused kernel returning ``(y, rstd)``. The *input* is
discarded; the output is retained anyway (the next matmul needs it), so
the only per-activation memory this layer adds is the per-row ``rstd``
(``1/sqrt(var + eps)``) — B·S floats instead of B·S·H.

Backward (Appendix D, lossless): with ``x̂ = (y - β)/γ`` and ``g = dy·γ``:

    dx = (g - mean(g·x̂)·x̂ - mean(g)) · rstd
    dγ = Σ_rows dy·x̂        dβ = Σ_rows dy

The derivation extends In-Place Activated BatchNorm [Rota Bulò et al.,
CVPR'18] to LayerNorm's per-row statistics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS_DEFAULT = 1e-12  # HuggingFace BERT LayerNorm eps

_BLOCK_ROWS = 128


def _rows(x):
    return x.reshape(x.size // x.shape[-1], x.shape[-1])


def _pad_rows(x2, block):
    n = x2.shape[0]
    pad = (-n) % block
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, x2.shape[1]), x2.dtype)], axis=0)
    return x2, n


# --------------------------------------------------------------------------
# jnp fast path
# --------------------------------------------------------------------------


def layernorm_fwd_jnp(x, gamma, beta, eps: float = EPS_DEFAULT):
    """Fused forward: (y, rstd). rstd has the row shape (last axis dropped)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + eps)
    y = (x - mu) * rstd * gamma + beta
    return y, rstd[..., 0]


def layernorm_bwd_jnp(dy, y, gamma, beta, rstd):
    """Output-based backward. Returns (dx, dgamma, dbeta)."""
    rstd = rstd[..., None]
    xhat = (y - beta) / gamma
    g = dy * gamma
    red = tuple(range(y.ndim - 1))
    dgamma = jnp.sum(dy * xhat, axis=red)
    dbeta = jnp.sum(dy, axis=red)
    mean_g = jnp.mean(g, axis=-1, keepdims=True)
    mean_gx = jnp.mean(g * xhat, axis=-1, keepdims=True)
    dx = (g - mean_gx * xhat - mean_g) * rstd
    return dx, dgamma, dbeta


# --------------------------------------------------------------------------
# Pallas kernels. Row-tiled; γ/β ride along whole (they are H-sized).
# The backward kernel emits *per-block partial* dγ/dβ that the host-side
# wrapper sums — mirroring how a TPU kernel would accumulate partials in
# VMEM scratch and reduce across the grid.
# --------------------------------------------------------------------------


def layernorm_fwd_pallas(x, gamma, beta, eps: float = EPS_DEFAULT, block_rows: int = _BLOCK_ROWS):
    orig_shape = x.shape
    x2, n = _pad_rows(_rows(x), block_rows)
    rows, cols = x2.shape

    def kernel(x_ref, g_ref, b_ref, y_ref, r_ref):
        xv = x_ref[...]
        mu = jnp.mean(xv, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xv - mu), axis=-1, keepdims=True)
        rstd = 1.0 / jnp.sqrt(var + eps)
        y_ref[...] = (xv - mu) * rstd * g_ref[...] + b_ref[...]
        r_ref[...] = rstd[..., 0]

    y2, r2 = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), x.dtype),
            jax.ShapeDtypeStruct((rows,), x.dtype),
        ],
        interpret=True,
    )(x2, gamma, beta)
    return y2[:n].reshape(orig_shape), r2[:n].reshape(orig_shape[:-1])


def layernorm_bwd_pallas(dy, y, gamma, beta, rstd, block_rows: int = _BLOCK_ROWS):
    orig_shape = y.shape
    dy2, n = _pad_rows(_rows(dy), block_rows)
    y2, _ = _pad_rows(_rows(y), block_rows)
    r2, _ = _pad_rows(rstd.reshape(-1, 1), block_rows)
    rows, cols = y2.shape
    nblk = rows // block_rows

    def kernel(dy_ref, y_ref, r_ref, g_ref, b_ref, dx_ref, dg_ref, db_ref):
        dyv, yv = dy_ref[...], y_ref[...]
        rstd_v = r_ref[...]  # [block, 1]
        gam, bet = g_ref[...], b_ref[...]
        xhat = (yv - bet) / gam
        g = dyv * gam
        mean_g = jnp.mean(g, axis=-1, keepdims=True)
        mean_gx = jnp.mean(g * xhat, axis=-1, keepdims=True)
        dx_ref[...] = (g - mean_gx * xhat - mean_g) * rstd_v
        dg_ref[...] = jnp.sum(dyv * xhat, axis=0)[None, :]
        db_ref[...] = jnp.sum(dyv, axis=0)[None, :]

    dx2, dg_part, db_part = pl.pallas_call(
        kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), y.dtype),
            jax.ShapeDtypeStruct((nblk, cols), y.dtype),
            jax.ShapeDtypeStruct((nblk, cols), y.dtype),
        ],
        interpret=True,
    )(dy2, y2, r2, gamma, beta)
    dx = dx2[:n].reshape(orig_shape)
    return dx, dg_part.sum(axis=0), db_part.sum(axis=0)
