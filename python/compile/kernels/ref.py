"""Pure-jnp reference oracle for every Tempo kernel.

These are the "textbook" implementations: forward passes written in plain
``jax.numpy`` with no custom_vjp, so ``jax.grad`` of these is the ground
truth the Tempo backward derivations (and the Pallas kernels) are checked
against in ``python/tests/``.

They also serve as the *baseline* compute path (what PyTorch autograd
would do), and document which tensors standard autodiff retains — the
inventory mirrored by ``rust/src/memmodel``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

SQRT_2 = 1.4142135623730951
SQRT_2_PI = 2.5066282746310002  # sqrt(2*pi)

# Location of the GELU minimum (solved to f64 precision in gelu.py; the
# constant is duplicated here so the oracle has no dependency on the
# kernel module).
GELU_XSTAR = -0.7517915246935645


def erf(x):
    """Polynomial erf (Abramowitz & Stegun 7.1.26, |err| ≤ 1.5e-7).

    Used instead of ``jax.lax.erf`` because the latter lowers to the
    dedicated ``erf`` HLO opcode, which the image's xla_extension 0.5.1
    HLO parser predates — this form lowers to plain mul/add/exp and is
    exact at float32 precision.
    """
    a1, a2, a3, a4, a5 = (
        0.254829592,
        -0.284496736,
        1.421413741,
        -1.453152027,
        1.061405429,
    )
    p = 0.3275911
    s = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + p * ax)
    poly = ((((a5 * t + a4) * t + a3) * t + a2) * t + a1) * t
    return s * (1.0 - poly * jnp.exp(-ax * ax))


def phi(x):
    """Standard normal pdf."""
    return jnp.exp(-0.5 * jnp.square(x)) / SQRT_2_PI


def Phi(x):
    """Standard normal cdf, cancellation-free.

    For x < 0 the naive ``0.5*(1+erf)`` computes ``1 - (1-tiny)`` and
    loses all precision; the A&S polynomial actually yields
    ``erfc(|z|) = poly(t)·exp(-z²)`` directly, so we branch on sign.
    """
    a1, a2, a3, a4, a5 = (
        0.254829592,
        -0.284496736,
        1.421413741,
        -1.453152027,
        1.061405429,
    )
    p = 0.3275911
    z = jnp.abs(x) / SQRT_2
    t = 1.0 / (1.0 + p * z)
    erfc = ((((a5 * t + a4) * t + a3) * t + a2) * t + a1) * t * jnp.exp(-z * z)
    return jnp.where(x >= 0, 1.0 - 0.5 * erfc, 0.5 * erfc)


def gelu(x):
    """Exact (erf-based) GELU, matching torch.nn.GELU's default.

    Computed in f32 internally — bf16 evaluation of the cdf polynomial
    loses most of the mantissa (the TPU VPU likewise upcasts).
    """
    out_dt = x.dtype
    x = x.astype(jnp.float32)
    return (x * Phi(x)).astype(out_dt)


def gelu_grad(x):
    """d GELU / dx in terms of the *input* (what autodiff stashes x for)."""
    out_dt = x.dtype
    x = x.astype(jnp.float32)
    return (Phi(x) + x * phi(x)).astype(out_dt)


def layernorm(x, gamma, beta, eps: float = 1e-12):
    """LayerNorm over the last axis (HuggingFace BERT default eps)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    xhat = (x - mu) / jnp.sqrt(var + eps)
    return xhat * gamma + beta


def layernorm_stats(x, eps: float = 1e-12):
    """(mean, rstd) the in-place variant stashes instead of the input."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return mu, 1.0 / jnp.sqrt(var + eps)


def softmax(x, axis: int = -1):
    """Numerically-stable softmax (the baseline retains both x and y)."""
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def dropout(x, mask, p: float):
    """Dropout given a precomputed keep-mask (1 = keep).

    Mask generation is factored out so baseline and Tempo paths consume
    bit-identical masks (the paper stashes the very mask the forward drew).
    """
    if p <= 0.0:
        return x
    return x * mask.astype(x.dtype) / (1.0 - p)


def attention(q, k, v, attn_bias, drop_mask, p: float):
    """Reference scaled-dot-product attention with prob-dropout.

    q, k, v: [B, A, S, D]; attn_bias: broadcastable to [B, A, S, S]
    (additive, -inf style padding mask); drop_mask: [B, A, S, S] keep-mask.

    Returns context [B, A, S, D].
    """
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(float(d))
    s = s + attn_bias
    probs = softmax(s, axis=-1)
    dropped = dropout(probs, drop_mask, p)
    return jnp.einsum("bhqk,bhkd->bhqd", dropped, v)


# ---------------------------------------------------------------------------
# Closed-form backward passes (used to unit-test the Tempo derivations
# independently of jax.grad, as a second line of defence).
# ---------------------------------------------------------------------------


def softmax_bwd_from_output(dy, y, axis: int = -1):
    """Output-only softmax backward: dx = (dy - sum(dy*y)) * y."""
    s = jnp.sum(dy * y, axis=axis, keepdims=True)
    return (dy - s) * y


def layernorm_bwd_from_output(dy, y, gamma, beta, rstd):
    """Appendix D: gradients of LayerNorm from its *output*.

    xhat is reconstructed as (y - beta) / gamma; requires |gamma| > 0.
    Returns (dx, dgamma, dbeta).
    """
    xhat = (y - beta) / gamma
    g = dy * gamma
    dgamma = jnp.sum(dy * xhat, axis=tuple(range(y.ndim - 1)))
    dbeta = jnp.sum(dy, axis=tuple(range(y.ndim - 1)))
    mean_g = jnp.mean(g, axis=-1, keepdims=True)
    mean_gx = jnp.mean(g * xhat, axis=-1, keepdims=True)
    dx = (g - mean_gx * xhat - mean_g) * rstd
    return dx, dgamma, dbeta


def gelu_bwd_from_input(dy, x):
    """Baseline GELU backward (retains x)."""
    return dy * gelu_grad(x)
