"""Fused multi-head attention core (Fig 1 ① — the O(S²) hot spot).

Forward (one kernel per (batch·head) grid cell):

    scores = q·kᵀ/√d + bias → probs = softmax(scores)
    dropped = probs · mask/(1-p) → ctx = dropped · v

Tempo residuals: ``probs`` (the softmax *output* — required by the
output-only softmax backward anyway) and the int8 ``mask``. The baseline
would additionally retain ``scores`` (softmax input) and ``dropped``
(dropout output) — two more O(B·A·S²) float maps; Tempo's softmax
optimization and Sub-Layer Dropout Recomputation discard both.

Backward recomputes ``dropped = probs·mask/(1-p)`` (one multiply) where
the dV matmul needs it, then applies the output-only softmax backward.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import dropout as drp
from . import softmax as sm


# --------------------------------------------------------------------------
# jnp fast path. q,k,v: [B, A, S, D]; bias broadcastable to [B, A, S, S].
# --------------------------------------------------------------------------


def attention_fwd_jnp(q, k, v, bias, mask, p: float):
    """Returns (ctx, probs) — probs is the only float residual retained."""
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) * (1.0 / jnp.sqrt(float(d)))
    scores = scores + bias
    probs = sm.softmax_fwd_jnp(scores)
    dropped = drp.dropout_apply_jnp(probs, mask, p)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", dropped, v)
    return ctx, probs


def attention_bwd_jnp(dctx, q, k, v, probs, mask, p: float):
    """Backward from Tempo residuals only. Returns (dq, dk, dv)."""
    d = q.shape[-1]
    # Sub-layer dropout recomputation: rebuild `dropped` for the dV matmul.
    dropped = drp.dropout_apply_jnp(probs, mask, p)
    dv = jnp.einsum("bhqk,bhqd->bhkd", dropped, dctx)
    ddropped = jnp.einsum("bhqd,bhkd->bhqk", dctx, v)
    dprobs = drp.dropout_bwd_jnp(ddropped, mask, p)
    dscores = sm.softmax_bwd_jnp(dprobs, probs)  # output-only softmax bwd
    scale = 1.0 / jnp.sqrt(float(d))
    dq = jnp.einsum("bhqk,bhkd->bhqd", dscores, k) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", dscores, q) * scale
    return dq, dk, dv


# --------------------------------------------------------------------------
# Pallas fused forward: grid over B·A, whole-S tiles in VMEM. On real TPU
# this would be further blocked over S (flash-style); interpret mode keeps
# the structure while staying runnable on CPU PJRT.
# --------------------------------------------------------------------------


def attention_fwd_pallas(q, k, v, bias, mask, p: float):
    b, h, sq, d = q.shape
    bias_full = jnp.broadcast_to(bias, (b, h, sq, sq)).astype(q.dtype)
    scale = 1.0 / math.sqrt(float(d))
    inv_keep = 1.0 / (1.0 - p) if p > 0.0 else 1.0

    def kernel(q_ref, k_ref, v_ref, b_ref, m_ref, ctx_ref, probs_ref):
        qv = q_ref[0, 0]
        kv = k_ref[0, 0]
        vv = v_ref[0, 0]
        scores = jnp.dot(qv, kv.T) * scale + b_ref[0, 0]
        mx = jnp.max(scores, axis=-1, keepdims=True)
        e = jnp.exp(scores - mx)
        probs = e / jnp.sum(e, axis=-1, keepdims=True)
        dropped = probs * m_ref[0, 0].astype(probs.dtype) * inv_keep
        ctx_ref[0, 0] = jnp.dot(dropped, vv)
        probs_ref[0, 0] = probs

    grid = (b, h)
    qspec = pl.BlockSpec((1, 1, sq, d), lambda i, j: (i, j, 0, 0))
    sspec = pl.BlockSpec((1, 1, sq, sq), lambda i, j: (i, j, 0, 0))
    ctx, probs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec, sspec, sspec],
        out_specs=[qspec, sspec],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, sq, sq), q.dtype),
        ],
        interpret=True,
    )(q, k, v, bias_full, mask.astype(jnp.int8))
    return ctx, probs


def attention_bwd_pallas(dctx, q, k, v, probs, mask, p: float):
    b, h, s, d = q.shape
    scale = 1.0 / math.sqrt(float(d))
    inv_keep = 1.0 / (1.0 - p) if p > 0.0 else 1.0

    def kernel(dc_ref, q_ref, k_ref, v_ref, p_ref, m_ref, dq_ref, dk_ref, dv_ref):
        dc = dc_ref[0, 0]
        qv, kv, vv = q_ref[0, 0], k_ref[0, 0], v_ref[0, 0]
        probs_v = p_ref[0, 0]
        mk = m_ref[0, 0].astype(probs_v.dtype) * inv_keep
        dropped = probs_v * mk  # sub-layer recomputation
        dv_ref[0, 0] = jnp.dot(dropped.T, dc)
        ddropped = jnp.dot(dc, vv.T)
        dprobs = ddropped * mk
        ssum = jnp.sum(dprobs * probs_v, axis=-1, keepdims=True)
        dscores = (dprobs - ssum) * probs_v
        dq_ref[0, 0] = jnp.dot(dscores, kv) * scale
        dk_ref[0, 0] = jnp.dot(dscores.T, qv) * scale

    qspec = pl.BlockSpec((1, 1, s, d), lambda i, j: (i, j, 0, 0))
    sspec = pl.BlockSpec((1, 1, s, s), lambda i, j: (i, j, 0, 0))
    dq, dk, dv = pl.pallas_call(
        kernel,
        grid=(b, h),
        in_specs=[qspec, qspec, qspec, qspec, sspec, sspec],
        out_specs=[qspec, qspec, qspec],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
            jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        ],
        interpret=True,
    )(dctx, q, k, v, probs, mask.astype(jnp.int8))
    return dq, dk, dv
