"""Sub-Layer Dropout Recomputation (Tempo §3.3, Appendix E.3/F.3).

Dropout's forward produces two tensors: the boolean keep-mask and the
scaled output. Whole-layer checkpointing would recompute *both*; Tempo
observes that stashing only the 1-byte mask and recomputing the output
(`y = x · mask / (1-p)`, one elementwise multiply) keeps ~4/5 of the
memory benefit at negligible cost — critical for the O(S²) attention
probabilities.

Masks are drawn outside the kernel (threefry bits from the step key), so
baseline / Tempo / recomputation paths consume bit-identical masks and
the recomputed output is exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_BLOCK_ROWS = 256


def make_mask(key, shape, p: float):
    """Draw the keep-mask (1 = keep) as int8, the paper's 8-bit bool."""
    if p <= 0.0:
        return jnp.ones(shape, jnp.int8)
    return jax.random.bernoulli(key, 1.0 - p, shape).astype(jnp.int8)


def dropout_apply_jnp(x, mask, p: float):
    """Forward *and* recomputation: y = x * mask / (1-p)."""
    if p <= 0.0:
        return x
    return x * mask.astype(x.dtype) * (1.0 / (1.0 - p))


def dropout_bwd_jnp(dy, mask, p: float):
    """dx = dy * mask / (1-p) — needs only the mask."""
    return dropout_apply_jnp(dy, mask, p)


def _rows(x):
    return x.reshape(x.size // x.shape[-1], x.shape[-1])


def _pad_rows(x2, block):
    n = x2.shape[0]
    pad = (-n) % block
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, x2.shape[1]), x2.dtype)], axis=0)
    return x2, n


def dropout_apply_pallas(x, mask, p: float, block_rows: int = _BLOCK_ROWS):
    """Fused mask-multiply-scale kernel (also the recomputation kernel)."""
    if p <= 0.0:
        return x
    orig = x.shape
    x2, n = _pad_rows(_rows(x), block_rows)
    m2, _ = _pad_rows(_rows(mask.astype(jnp.int8)), block_rows)
    rows, cols = x2.shape
    scale = 1.0 / (1.0 - p)

    def kernel(x_ref, m_ref, y_ref):
        y_ref[...] = x_ref[...] * m_ref[...].astype(x_ref.dtype) * scale

    y2 = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=True,
    )(x2, m2)
    return y2[:n].reshape(orig)
