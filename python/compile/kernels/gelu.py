"""In-place GELU (Tempo §3.1, Appendix E.1/F.1).

Forward: a single fused kernel ``gelu_fwd`` returns ``(y, m)`` where
``y = GELU(x)`` and ``m`` is the paper's one-byte mask recording whether
the input lies right of the GELU minimum ``x* ≈ -0.75179``. The input
``x`` is *discarded* — it is recoverable from ``(y, m)`` because GELU is
one-to-one on each side of its unique minimum.

Backward: ``gelu_bwd(dy, y, m) = dy * g(y, m)`` where
``g = GELU' ∘ GELU*⁻¹`` (paper Eq. 2) — the derivative expressed directly
in terms of the *output*. GELU is transcendental so ``g`` has no
closed-form; following Appendix F.1 we approximate it with piecewise
polynomials of degree ≤ 13.

Approximation detail (improves on a naive fit in ``y``): near the
minimum, ``y - y* ~ c (x - x*)²``, so ``g`` behaves like ``±sqrt(y - y*)``
— polynomials in ``y`` converge miserably there. We instead fit
polynomials in ``u = sqrt(y - y*)``, in which ``g`` is analytic across
the minimum; a handful of segments per branch then reaches ~1e-4 max
error at degree ≤ 13. The far positive tail uses the exact derivative
evaluated at ``x ≈ y`` (GELU(x) → x); the far negative tail clamps to 0
(|g| < 6e-4 there). The tolerance/degree/segment knobs are the paper's
"tunable lossy" tradeoff.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

# --------------------------------------------------------------------------
# The GELU minimum, solved once in float64.
# --------------------------------------------------------------------------


def _gelu64(x: np.ndarray) -> np.ndarray:
    from math import erf

    v = np.vectorize(lambda t: t * 0.5 * (1.0 + erf(t / np.sqrt(2.0))))
    return v(x)


def _gelu_grad64(x: np.ndarray) -> np.ndarray:
    from math import erf

    pdf = lambda t: np.exp(-0.5 * t * t) / np.sqrt(2 * np.pi)  # noqa: E731
    cdf = lambda t: 0.5 * (1.0 + erf(t / np.sqrt(2.0)))  # noqa: E731
    v = np.vectorize(lambda t: cdf(t) + t * pdf(t))
    return v(x)


def _solve_xstar() -> float:
    """Bisection for the root of GELU' (unique minimum of GELU)."""
    lo, hi = -1.0, -0.5
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if _gelu_grad64(np.array(mid)) > 0:
            hi = mid
        else:
            lo = mid
    return 0.5 * (lo + hi)


XSTAR: float = float(_solve_xstar())  # ≈ -0.7517916243...
YSTAR: float = float(_gelu64(np.array(XSTAR)))  # ≈ -0.1699935...

# Positive-branch analytic tail: for y >= Y_HI, x - y < 1e-8 so we can
# evaluate GELU'(y) directly.
Y_HI = 6.0
# Negative-branch clamp: for x <= X_LO_CLAMP the derivative magnitude is
# < 6e-4 and we return 0. In u-space this is u >= U_CLAMP_NEG.
X_LO_CLAMP = -4.0


@dataclass(frozen=True)
class GeluApprox:
    """Piecewise-polynomial approximation of g(y, m) = GELU'(GELU*⁻¹(y, m)).

    Polynomials are in u = sqrt(y - y*). ``bounds_*`` are the segment
    right-edges in u-space (last edge = branch end); ``coeffs_*`` is an
    [n_seg, degree+1] table, highest power first (Horner order).
    """

    degree: int
    bounds_pos: tuple
    coeffs_pos: tuple  # tuple of tuples
    bounds_neg: tuple
    coeffs_neg: tuple
    max_err_pos: float
    max_err_neg: float

    @staticmethod
    @functools.lru_cache(maxsize=8)
    def fit(degree: int = 11, n_seg_pos: int = 6, n_seg_neg: int = 6) -> "GeluApprox":
        """Least-squares fit on dense Chebyshev-style samples per segment.

        Deterministic and fast (<50 ms); run at import/build time, the
        coefficient table is baked into the lowered HLO as constants.
        """

        def fit_branch(x_lo: float, x_hi: float, n_seg: int):
            # Dense x-grid on the branch; map to (u, g) samples.
            xs = np.linspace(x_lo, x_hi, 20001, dtype=np.float64)
            ys = _gelu64(xs)
            us = np.sqrt(np.maximum(ys - YSTAR, 0.0))
            gs = _gelu_grad64(xs)
            u_max = float(us.max())
            # Geometric-ish segmentation: denser near u=0 (the minimum),
            # where curvature of g(u) is highest on the negative branch.
            edges = u_max * (np.linspace(0, 1, n_seg + 1) ** 1.3)
            bounds, coeffs, max_err = [], [], 0.0
            for i in range(n_seg):
                lo, hi = edges[i], edges[i + 1]
                sel = (us >= lo) & (us <= hi)
                if sel.sum() < degree + 2:  # widen degenerate segments
                    sel = (us >= lo - 1e-6) & (us <= hi + 1e-6)
                u_s, g_s = us[sel], gs[sel]
                # Fit in a shifted variable for conditioning.
                c = np.polyfit(u_s - lo, g_s, degree)
                err = float(np.abs(np.polyval(c, u_s - lo) - g_s).max())
                max_err = max(max_err, err)
                bounds.append(float(hi))
                coeffs.append(tuple(float(v) for v in c))
            return tuple(bounds), tuple(coeffs), max_err

        bp, cp, ep = fit_branch(XSTAR, Y_HI + 0.25, n_seg_pos)
        bn, cn, en = fit_branch(X_LO_CLAMP, XSTAR, n_seg_neg)
        return GeluApprox(
            degree=degree,
            bounds_pos=bp,
            coeffs_pos=cp,
            bounds_neg=bn,
            coeffs_neg=cn,
            max_err_pos=ep,
            max_err_neg=en,
        )

    # -- evaluation (pure jnp; used inside both the pallas kernel and the
    #    jnp fast path, so the two lower to identical math). The tables are
    #    threaded as explicit arrays so the pallas kernel can take them as
    #    inputs (pallas forbids captured array constants). ---------------

    def tables(self, dtype=jnp.float32) -> dict:
        """Materialize the coefficient tables as jnp arrays."""

        def branch(bounds, coeffs):
            return dict(
                inner=jnp.asarray(bounds[:-1], dtype),  # inner right-edges
                lefts=jnp.asarray((0.0,) + bounds[:-1], dtype),
                table=jnp.asarray(coeffs, dtype),  # [n_seg, degree+1]
            )

        # NOTE: only rank>=1 arrays here — the tables ride through
        # pallas_call as inputs, and rank-0 blocks lower to a malformed
        # dynamic_slice under interpret mode. Scalars (u_clamp, YSTAR,
        # Y_HI) are python floats inlined as HLO constants instead.
        return dict(
            pos=branch(self.bounds_pos, self.coeffs_pos),
            neg=branch(self.bounds_neg, self.coeffs_neg),
        )

    def _eval_branch(self, u, br):
        # Segment id via direct compares, then one-hot × table contraction
        # instead of a gather: lowers to plain compare/mul/add (parseable
        # by the old HLO toolchain, and maps onto the TPU MXU as a skinny
        # [N, n_seg] @ [n_seg, degree+1] matmul).
        inner = br["inner"]  # [n_seg-1] inner right-edges
        n_seg = br["table"].shape[0]
        seg = jnp.sum((u[..., None] > inner).astype(u.dtype), axis=-1)
        onehot = (seg[..., None] == jnp.arange(n_seg, dtype=u.dtype)).astype(u.dtype)
        c = jnp.einsum("...s,sk->...k", onehot, br["table"])
        t = u - jnp.einsum("...s,s->...", onehot, br["lefts"])
        acc = c[..., 0]
        for k in range(1, self.degree + 1):
            acc = acc * t + c[..., k]
        return acc

    def g_of_y_tabled(self, y, m, tabs):
        """g(y, m) from explicit tables (pallas-kernel friendly).

        Always computes in f32: a degree-11 Horner chain in bf16 loses
        ~all mantissa bits (the TPU VPU would also evaluate this in f32
        and round once at the end).
        """
        out_dt = y.dtype
        y = y.astype(jnp.float32)
        dt = y.dtype
        u = jnp.sqrt(jnp.maximum(y - jnp.asarray(YSTAR, dt), 0.0))
        g_pos_poly = self._eval_branch(u, tabs["pos"])
        # analytic positive tail: x ≈ y for y >= Y_HI
        g_pos = jnp.where(y >= Y_HI, ref.gelu_grad(y), g_pos_poly)
        g_neg_poly = self._eval_branch(u, tabs["neg"])
        u_clamp = jnp.asarray(self.bounds_neg[-1], dt)
        g_neg = jnp.where(u >= u_clamp, jnp.zeros_like(y), g_neg_poly)
        keep = m.astype(jnp.bool_) if m.dtype != jnp.bool_ else m
        return jnp.where(keep, g_pos, g_neg).astype(out_dt)

    def g_of_y(self, y, m):
        """g(y, m): derivative factor from output + mask. Pure jnp."""
        # Tables stay f32 regardless of the activation dtype — a
        # degree-11 polynomial with bf16-rounded coefficients is garbage.
        return self.g_of_y_tabled(y, m, self.tables(jnp.float32))


DEFAULT_APPROX = GeluApprox.fit()


# --------------------------------------------------------------------------
# jnp fast path (identical math, no pallas_call wrapper)
# --------------------------------------------------------------------------


def gelu_fwd_jnp(x):
    """Fused forward: (y, mask). Mask is int8 per the paper (footnote 3)."""
    y = ref.gelu(x)
    m = (x >= jnp.asarray(XSTAR, x.dtype)).astype(jnp.int8)
    return y, m


def gelu_bwd_jnp(dy, y, m, approx: GeluApprox = DEFAULT_APPROX):
    """dx = dy * g(y, m) — single fused elementwise pass."""
    return dy * approx.g_of_y(y, m)


# --------------------------------------------------------------------------
# Pallas kernels (interpret=True — CPU PJRT cannot run Mosaic calls).
# Row-tiled: the last dim is kept whole (lane dim), leading dims collapse
# into a 1-D grid of row-blocks sized for VMEM.
# --------------------------------------------------------------------------

_BLOCK_ROWS = 256


def _flatten_rows(x):
    n = x.size // x.shape[-1]
    return x.reshape(n, x.shape[-1])


def _pad_rows(x2, block):
    n = x2.shape[0]
    pad = (-n) % block
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, x2.shape[1]), x2.dtype)], axis=0)
    return x2, n


def gelu_fwd_pallas(x, block_rows: int = _BLOCK_ROWS):
    """Pallas fused GELU forward producing (y, mask) in one kernel."""
    orig_shape = x.shape
    x2, n = _pad_rows(_flatten_rows(x), block_rows)
    rows, cols = x2.shape

    def kernel(x_ref, y_ref, m_ref):
        xv = x_ref[...]
        y_ref[...] = ref.gelu(xv)
        m_ref[...] = (xv >= jnp.asarray(XSTAR, xv.dtype)).astype(jnp.int8)

    y2, m2 = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), x.dtype),
            jax.ShapeDtypeStruct((rows, cols), jnp.int8),
        ],
        interpret=True,
    )(x2)
    return y2[:n].reshape(orig_shape), m2[:n].reshape(orig_shape)


def gelu_bwd_pallas(
    dy, y, m, approx: GeluApprox = DEFAULT_APPROX, block_rows: int = _BLOCK_ROWS
):
    """Pallas fused GELU backward: dx = dy * g(y, m).

    The coefficient tables ride along as (tiny, unblocked) kernel inputs;
    on a real TPU they would live in SMEM/VMEM for the whole grid.
    """
    orig_shape = y.shape
    dy2, n = _pad_rows(_flatten_rows(dy), block_rows)
    y2, _ = _pad_rows(_flatten_rows(y), block_rows)
    m2, _ = _pad_rows(_flatten_rows(m.astype(jnp.int8)), block_rows)
    rows, cols = y2.shape
    tabs = approx.tables(y.dtype)
    flat_tabs, tree = jax.tree_util.tree_flatten(tabs)

    def kernel(dy_ref, y_ref, m_ref, *rest):
        tab_refs, dx_ref = rest[:-1], rest[-1]
        tabs_in = jax.tree_util.tree_unflatten(tree, [r[...] for r in tab_refs])
        dx_ref[...] = dy_ref[...] * approx.g_of_y_tabled(y_ref[...], m_ref[...], tabs_in)

    whole = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)  # noqa: E731
    dx2 = pl.pallas_call(
        kernel,
        grid=(rows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        ]
        + [whole(a) for a in flat_tabs],
        out_specs=pl.BlockSpec((block_rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), y.dtype),
        interpret=True,
    )(dy2, y2, m2, *flat_tabs)
    return dx2[:n].reshape(orig_shape)
