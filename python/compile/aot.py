"""AOT export: lower (init / train_step / eval) to HLO *text* + manifest.

HLO text — NOT ``lowered.compiler_ir().serialize()`` — is the interchange
format: the image's xla_extension 0.5.1 rejects jax≥0.5 protos (64-bit
instruction ids); the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

ABI (mirrored by rust/src/runtime/artifact.rs):

    init : (seed:i32) → (params…, m…, v…)                 3n leaves
    step : (params…, m…, v…, input_ids, token_type_ids,
            attention_mask, labels, step:i32, seed:i32,
            lr:f32) → (params…, m…, v…, loss:f32)
    eval : (params…, input_ids, token_type_ids,
            attention_mask, labels, seed:i32) → (loss, metric)

Leaf order is jax's tree-flatten order over the nested param dict
(sorted keys), recorded explicitly in ``manifest.json``.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "name", p))))
    return ".".join(parts)


def param_spec(cfg: M.ModelConfig):
    """(names, shapes, dtypes, treedef) in flatten order."""
    shapes = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    names = [_leaf_name(p) for p, _ in flat]
    specs = [l for _, l in flat]
    return names, specs, treedef


def _dtype_str(dt) -> str:
    return jnp.dtype(dt).name


def export_artifact(cfg: M.ModelConfig, task: str, batch_size: int,
                    outdir: pathlib.Path, name: str) -> dict:
    """Lower init/step/eval for one (config, task, batch) and write files."""
    adir = outdir / name
    adir.mkdir(parents=True, exist_ok=True)
    names, specs, treedef = param_spec(cfg)
    n = len(specs)
    i32 = jnp.int32
    scalar_i32 = jax.ShapeDtypeStruct((), i32)
    scalar_f32 = jax.ShapeDtypeStruct((), jnp.float32)
    batch_struct = T.make_batch_struct(cfg, batch_size)
    batch_order = ["input_ids", "token_type_ids", "attention_mask", "labels"]
    batch_specs = [batch_struct[k] for k in batch_order]

    def unflatten(leaves):
        return jax.tree_util.tree_unflatten(treedef, list(leaves))

    # ---- init ------------------------------------------------------------
    init_fn = T.make_init_fn(cfg)

    def init_flat(seed):
        params, m, v = init_fn(seed)
        return tuple(
            jax.tree_util.tree_leaves(params)
            + jax.tree_util.tree_leaves(m)
            + jax.tree_util.tree_leaves(v)
        )

    init_lowered = jax.jit(init_flat, keep_unused=True).lower(scalar_i32)
    (adir / "init.hlo.txt").write_text(to_hlo_text(init_lowered))

    # ---- step ------------------------------------------------------------
    step_fn = T.make_train_step_fn(cfg, task)

    def step_flat(*args):
        p = unflatten(args[0:n])
        m = unflatten(args[n : 2 * n])
        v = unflatten(args[2 * n : 3 * n])
        ii, tt, am, lb = args[3 * n : 3 * n + 4]
        step, seed, lr = args[3 * n + 4 :]
        np_, nm, nv, loss = step_fn(p, m, v, ii, tt, am, lb, step, seed, lr)
        return tuple(
            jax.tree_util.tree_leaves(np_)
            + jax.tree_util.tree_leaves(nm)
            + jax.tree_util.tree_leaves(nv)
            + [loss]
        )

    step_args = list(specs) * 3 + batch_specs + [scalar_i32, scalar_i32, scalar_f32]
    step_lowered = jax.jit(step_flat, keep_unused=True).lower(*step_args)
    (adir / "step.hlo.txt").write_text(to_hlo_text(step_lowered))

    # ---- eval ------------------------------------------------------------
    eval_fn = T.make_eval_fn(cfg, task)

    def eval_flat(*args):
        p = unflatten(args[0:n])
        ii, tt, am, lb = args[n : n + 4]
        seed = args[n + 4]
        loss, metric = eval_fn(p, ii, tt, am, lb, seed)
        return (loss, metric)

    eval_args = list(specs) + batch_specs + [scalar_i32]
    eval_lowered = jax.jit(eval_flat, keep_unused=True).lower(*eval_args)
    (adir / "eval.hlo.txt").write_text(to_hlo_text(eval_lowered))

    manifest = {
        "name": name,
        "task": task,
        "variant": cfg.variant,
        "impl": cfg.impl,
        "batch_size": batch_size,
        "config": {
            "name": cfg.name,
            "vocab_size": cfg.vocab_size,
            "hidden": cfg.hidden,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "seq_len": cfg.seq_len,
            "intermediate": cfg.intermediate,
            "dropout_p": cfg.dropout_p,
            "num_classes": cfg.num_classes,
        },
        "n_param_leaves": n,
        "params": [
            {"name": nm, "shape": list(s.shape), "dtype": _dtype_str(s.dtype)}
            for nm, s in zip(names, specs)
        ],
        "batch_inputs": [
            {"name": k, "shape": list(batch_struct[k].shape), "dtype": "int32"}
            for k in batch_order
        ],
        "scalar_inputs": {
            "step": [{"name": "step", "dtype": "int32"},
                      {"name": "seed", "dtype": "int32"},
                      {"name": "lr", "dtype": "float32"}],
            "eval": [{"name": "seed", "dtype": "int32"}],
        },
        "files": {"init": "init.hlo.txt", "step": "step.hlo.txt",
                  "eval": "eval.hlo.txt"},
    }
    (adir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


# Artifact matrix. `mini` at B=8 is the e2e example; `tiny` powers the fast
# tests and Fig 6a/6b analogues; pallas_smoke proves the L1 kernel path
# lowers/loads end-to-end.
ARTIFACTS = [
    ("bert_tiny_baseline", "tiny", "baseline", "jnp", "mlm", 8),
    ("bert_tiny_checkpoint", "tiny", "checkpoint", "jnp", "mlm", 8),
    ("bert_tiny_tempo", "tiny", "tempo", "jnp", "mlm", 8),
    ("bert_mini_baseline", "mini", "baseline", "jnp", "mlm", 8),
    ("bert_mini_tempo", "mini", "tempo", "jnp", "mlm", 8),
    ("cls_tiny_baseline", "tiny", "baseline", "jnp", "cls", 16),
    ("cls_tiny_tempo", "tiny", "tempo", "jnp", "cls", 16),
    ("pallas_smoke", "tiny", "tempo", "pallas", "mlm", 2),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-separated artifact names")
    args = ap.parse_args()
    outdir = pathlib.Path(args.outdir)
    only = set(args.only.split(",")) if args.only else None
    # Merge with an existing index so --only exports don't clobber it.
    index_path = outdir / "index.json"
    index = json.loads(index_path.read_text()) if index_path.exists() else []
    by_name = {e["name"]: e for e in index}
    for name, cfg_key, variant, impl, task, bs in ARTIFACTS:
        if only and name not in only:
            continue
        cfg = M.CONFIGS[cfg_key].with_variant(variant, impl)
        print(f"[aot] lowering {name} ({cfg_key}, {variant}, {impl}, {task}, B={bs})")
        manifest = export_artifact(cfg, task, bs, outdir, name)
        by_name[name] = {"name": name, "dir": name,
                         "n_param_leaves": manifest["n_param_leaves"]}
    ordered = [by_name[n] for n, *_ in ARTIFACTS if n in by_name]
    index_path.write_text(json.dumps(ordered, indent=2))
    print(f"[aot] index now lists {len(ordered)} artifacts in {outdir}")


if __name__ == "__main__":
    main()
