"""L2: BERT-family model (Fig 1 faithful) with selectable variant.

Variants (the paper's three compared techniques):

* ``baseline``   — plain autodiff everywhere (NVIDIA/HuggingFace BERT).
* ``checkpoint`` — per-encoder-layer rematerialization
  (``jax.checkpoint``), mirroring ``torch.utils.checkpoint`` applied at
  each Transformer encoder layer's input.
* ``tempo``      — In-place GELU + In-place LayerNorm + Sub-Layer Dropout
  Recomputation + output-only softmax (all four of §3).

Architecture is the HuggingFace BERT encoder (post-LN): embeddings
(word+position+segment → LN → dropout), L × [self-attention → add&LN →
FFN(4H, GELU) → add&LN], MLM head with tied decoder, and a sequence
classification head (the MRPC fine-tuning analogue).

Dropout masks are drawn in-graph from a scalar seed via fold_in per
(layer, site), so every variant consumes bit-identical masks — loss
curves are comparable point-for-point (Fig 6a).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from functools import partial

import jax
import jax.numpy as jnp

from . import layers as L
from .kernels import dropout as drp_k
from .kernels import ref

VARIANTS = ("baseline", "checkpoint", "tempo")

NEG_INF = -1e9  # additive attention-mask fill, matches HF BERT


@dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters (paper §2.1 notation: H, S, A, L)."""

    name: str = "bert-tiny"
    vocab_size: int = 4096
    hidden: int = 128  # H
    layers: int = 2  # L
    heads: int = 2  # A
    seq_len: int = 64  # S
    intermediate: int = 512  # 4H
    max_position: int = 512
    type_vocab: int = 2
    dropout_p: float = 0.1
    attn_dropout_p: float = 0.1
    num_classes: int = 2  # for the classification head
    variant: str = "baseline"
    impl: str = "jnp"  # kernel path: "jnp" | "pallas"

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def with_variant(self, variant: str, impl: str = "jnp") -> "ModelConfig":
        assert variant in VARIANTS, variant
        return replace(self, variant=variant, impl=impl)


# Predefined configs. `tiny` trains in seconds on the CPU PJRT client;
# `mini` is the e2e example scale; `base`/`large` exist for lowering/shape
# checks and the analytical models (training them on 1 CPU core is not
# realistic — see DESIGN.md §2).
CONFIGS = {
    "tiny": ModelConfig(name="bert-tiny", vocab_size=4096, hidden=128, layers=2,
                        heads=2, seq_len=64, intermediate=512),
    "mini": ModelConfig(name="bert-mini", vocab_size=8192, hidden=256, layers=4,
                        heads=4, seq_len=128, intermediate=1024),
    "small": ModelConfig(name="bert-small", vocab_size=16384, hidden=512, layers=6,
                         heads=8, seq_len=128, intermediate=2048),
    "base": ModelConfig(name="bert-base", vocab_size=30522, hidden=768, layers=12,
                        heads=12, seq_len=128, intermediate=3072),
    "large": ModelConfig(name="bert-large", vocab_size=30522, hidden=1024, layers=24,
                         heads=16, seq_len=128, intermediate=4096),
}


# --------------------------------------------------------------------------
# Parameter init (truncated-normal-ish; std 0.02 like BERT)
# --------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    """Nested param dict. Flattening order (sorted keys) is the ABI the
    Rust runtime relies on — see aot.py manifest."""
    std = 0.02
    k_iter = iter(jax.random.split(key, 16 + 16 * cfg.layers))

    def dense(kk, n_in, n_out):
        return {
            "w": jax.random.normal(kk, (n_in, n_out), jnp.float32) * std,
            "b": jnp.zeros((n_out,), jnp.float32),
        }

    def ln():
        return {
            "gamma": jnp.ones((cfg.hidden,), jnp.float32),
            "beta": jnp.zeros((cfg.hidden,), jnp.float32),
        }

    params = {
        "embeddings": {
            "word": jax.random.normal(next(k_iter), (cfg.vocab_size, cfg.hidden), jnp.float32) * std,
            "position": jax.random.normal(next(k_iter), (cfg.max_position, cfg.hidden), jnp.float32) * std,
            "token_type": jax.random.normal(next(k_iter), (cfg.type_vocab, cfg.hidden), jnp.float32) * std,
            "ln": ln(),
        },
        "encoder": {},
        "mlm": {
            "transform": dense(next(k_iter), cfg.hidden, cfg.hidden),
            "ln": ln(),
            "decoder_bias": jnp.zeros((cfg.vocab_size,), jnp.float32),
        },
        "cls": {
            "pooler": dense(next(k_iter), cfg.hidden, cfg.hidden),
            "classifier": dense(next(k_iter), cfg.hidden, cfg.num_classes),
        },
    }
    for i in range(cfg.layers):
        params["encoder"][f"layer_{i:02d}"] = {
            "attn": {
                "q": dense(next(k_iter), cfg.hidden, cfg.hidden),
                "k": dense(next(k_iter), cfg.hidden, cfg.hidden),
                "v": dense(next(k_iter), cfg.hidden, cfg.hidden),
                "o": dense(next(k_iter), cfg.hidden, cfg.hidden),
                "ln": ln(),
            },
            "ffn": {
                "fc1": dense(next(k_iter), cfg.hidden, cfg.intermediate),
                "fc2": dense(next(k_iter), cfg.intermediate, cfg.hidden),
                "ln": ln(),
            },
        }
    return params


# --------------------------------------------------------------------------
# Variant-dispatched primitive ops
# --------------------------------------------------------------------------


def _gelu(cfg, x):
    if cfg.variant == "tempo":
        return L.tempo_gelu(x, cfg.impl)
    return L.baseline_gelu(x)


def _layernorm(cfg, x, p):
    if cfg.variant == "tempo":
        return L.tempo_layernorm(x, p["gamma"], p["beta"], 1e-12, cfg.impl)
    return L.baseline_layernorm(x, p["gamma"], p["beta"])


def _dropout(cfg, x, key, p_rate, train):
    if not train or p_rate <= 0.0:
        return x
    mask = drp_k.make_mask(key, x.shape, p_rate)
    if cfg.variant == "tempo":
        return L.tempo_dropout(x, mask, p_rate, cfg.impl)
    return L.baseline_dropout(x, mask, p_rate)


def _attention_core(cfg, q, k, v, bias, key, train):
    p = cfg.attn_dropout_p if train else 0.0
    mask = drp_k.make_mask(key, (q.shape[0], q.shape[1], q.shape[2], q.shape[2]), p)
    if cfg.variant == "tempo":
        return L.tempo_attention(q, k, v, bias, mask, p, cfg.impl)
    return L.baseline_attention(q, k, v, bias, mask, p)


def _dense(p, x):
    return x @ p["w"] + p["b"]


# --------------------------------------------------------------------------
# Encoder
# --------------------------------------------------------------------------


def _split_heads(cfg, x):
    b, s, _ = x.shape
    return x.reshape(b, s, cfg.heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(cfg, x):
    b, h, s, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * d)


def encoder_layer(cfg: ModelConfig, p, x, bias, key, train: bool):
    """One Transformer encoder layer per Fig 1."""
    k_attn, k_hdrop1, k_hdrop2 = jax.random.split(key, 3)
    q = _split_heads(cfg, _dense(p["attn"]["q"], x))
    k = _split_heads(cfg, _dense(p["attn"]["k"], x))
    v = _split_heads(cfg, _dense(p["attn"]["v"], x))
    ctx = _attention_core(cfg, q, k, v, bias, k_attn, train)
    attn_out = _dense(p["attn"]["o"], _merge_heads(cfg, ctx))
    attn_out = _dropout(cfg, attn_out, k_hdrop1, cfg.dropout_p, train)
    x = _layernorm(cfg, x + attn_out, p["attn"]["ln"])
    h = _gelu(cfg, _dense(p["ffn"]["fc1"], x))
    h = _dense(p["ffn"]["fc2"], h)
    h = _dropout(cfg, h, k_hdrop2, cfg.dropout_p, train)
    return _layernorm(cfg, x + h, p["ffn"]["ln"])


def encode(cfg: ModelConfig, params, input_ids, token_type_ids, attention_mask,
           key, train: bool):
    """Embeddings + L encoder layers → hidden states [B, S, H]."""
    emb = params["embeddings"]
    b, s = input_ids.shape
    pos_ids = jnp.arange(s)[None, :]
    x = (
        emb["word"][input_ids]
        + emb["position"][pos_ids]
        + emb["token_type"][token_type_ids]
    )
    x = _layernorm(cfg, x, emb["ln"])
    k_emb, key = jax.random.split(key)
    x = _dropout(cfg, x, k_emb, cfg.dropout_p, train)
    # additive mask: [B, 1, 1, S], 0 where attended, NEG_INF where padded
    bias = (1.0 - attention_mask[:, None, None, :].astype(x.dtype)) * NEG_INF

    layer_keys = jax.random.split(key, cfg.layers)
    for i in range(cfg.layers):
        lp = params["encoder"][f"layer_{i:02d}"]
        if cfg.variant == "checkpoint":
            # PyTorch-style whole-layer checkpointing: stash only the layer
            # input, recompute everything inside during backward.
            layer_fn = jax.checkpoint(
                partial(encoder_layer, cfg, train=train),
                policy=jax.checkpoint_policies.nothing_saveable,
            )
            x = layer_fn(lp, x, bias, layer_keys[i])
        else:
            x = encoder_layer(cfg, lp, x, bias, layer_keys[i], train=train)
    return x


# --------------------------------------------------------------------------
# Heads and losses
# --------------------------------------------------------------------------


def mlm_logits(cfg: ModelConfig, params, hidden):
    """MLM head: transform → GELU → LN → tied decoder + bias."""
    p = params["mlm"]
    h = _dense(p["transform"], hidden)
    h = _gelu(cfg, h)
    h = _layernorm(cfg, h, p["ln"])
    return h @ params["embeddings"]["word"].T + p["decoder_bias"]


def mlm_loss(cfg: ModelConfig, params, batch, key, train: bool = True):
    """Masked-LM cross entropy; labels == -100 are ignored (HF convention)."""
    hidden = encode(cfg, params, batch["input_ids"], batch["token_type_ids"],
                    batch["attention_mask"], key, train)
    logits = mlm_logits(cfg, params, hidden)
    labels = batch["labels"]
    valid = labels >= 0
    safe_labels = jnp.where(valid, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe_labels[..., None], axis=-1)[..., 0]
    nll = jnp.where(valid, nll, 0.0)
    count = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / count.astype(nll.dtype)


def cls_logits(cfg: ModelConfig, params, hidden):
    """Sequence classification: tanh pooler over [CLS] → classifier."""
    p = params["cls"]
    pooled = jnp.tanh(_dense(p["pooler"], hidden[:, 0]))
    return _dense(p["classifier"], pooled)


def cls_loss(cfg: ModelConfig, params, batch, key, train: bool = True):
    hidden = encode(cfg, params, batch["input_ids"], batch["token_type_ids"],
                    batch["attention_mask"], key, train)
    logits = cls_logits(cfg, params, hidden)
    labels = batch["labels"][:, 0]  # [B] packed in column 0
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def cls_accuracy(cfg: ModelConfig, params, batch, key):
    hidden = encode(cfg, params, batch["input_ids"], batch["token_type_ids"],
                    batch["attention_mask"], key, train=False)
    logits = cls_logits(cfg, params, hidden)
    pred = jnp.argmax(logits, axis=-1)
    return jnp.mean((pred == batch["labels"][:, 0]).astype(jnp.float32))
