"""Training step: AdamW fused in-graph, lowered per (config, variant).

The exported ``train_step`` is a pure function

    (params, m, v, input_ids, token_type_ids, attention_mask, labels,
     step, seed, lr) → (params', m', v', loss)

so the Rust coordinator owns the schedule (lr as a scalar input) and the
PRNG stream (seed as a scalar input) while everything numeric stays
inside one XLA executable. Optimizer state and params round-trip as the
flat leaf list described by the AOT manifest.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import model as M

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
WEIGHT_DECAY = 0.01


def init_opt_state(params):
    zeros = lambda p: jnp.zeros_like(p)  # noqa: E731
    return jax.tree.map(zeros, params), jax.tree.map(zeros, params)


def _is_no_decay(path) -> bool:
    """BERT convention: no weight decay on biases and LayerNorm params."""
    names = {getattr(p, "key", getattr(p, "name", "")) for p in path}
    return bool(names & {"b", "beta", "gamma", "decoder_bias"})


def adamw_update(params, grads, m, v, step, lr):
    """One AdamW step (decoupled weight decay, bias-corrected)."""
    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - ADAM_B1**t
    c2 = 1.0 - ADAM_B2**t

    def upd(path, p, g, m_, v_):
        m_n = ADAM_B1 * m_ + (1.0 - ADAM_B1) * g
        v_n = ADAM_B2 * v_ + (1.0 - ADAM_B2) * jnp.square(g)
        update = (m_n / c1) / (jnp.sqrt(v_n / c2) + ADAM_EPS)
        if not _is_no_decay(path):
            update = update + WEIGHT_DECAY * p
        return p - lr * update, m_n, v_n

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    gs = jax.tree_util.tree_leaves(grads)
    ms = jax.tree_util.tree_leaves(m)
    vs = jax.tree_util.tree_leaves(v)
    out = [upd(path, p, g, m_, v_) for (path, p), g, m_, v_ in zip(flat, gs, ms, vs)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, new_m, new_v


def make_batch_struct(cfg: M.ModelConfig, batch_size: int):
    """ShapeDtypeStructs of the batch tensors (ABI with the Rust side)."""
    bs = (batch_size, cfg.seq_len)
    i32 = jnp.int32
    return {
        "input_ids": jax.ShapeDtypeStruct(bs, i32),
        "token_type_ids": jax.ShapeDtypeStruct(bs, i32),
        "attention_mask": jax.ShapeDtypeStruct(bs, i32),
        "labels": jax.ShapeDtypeStruct(bs, i32),
    }


def _rng(seed):
    return jax.random.PRNGKey(seed)


def train_step(cfg: M.ModelConfig, task: str, params, m, v,
               input_ids, token_type_ids, attention_mask, labels,
               step, seed, lr):
    """One optimizer step. task: 'mlm' | 'cls'."""
    batch = {
        "input_ids": input_ids,
        "token_type_ids": token_type_ids,
        "attention_mask": attention_mask,
        "labels": labels,
    }
    loss_fn = M.mlm_loss if task == "mlm" else M.cls_loss
    key = jax.random.fold_in(_rng(seed), step)

    def objective(p):
        return loss_fn(cfg, p, batch, key, train=True)

    loss, grads = jax.value_and_grad(objective)(params)
    new_p, new_m, new_v = adamw_update(params, grads, m, v, step, lr)
    return new_p, new_m, new_v, loss


def eval_step(cfg: M.ModelConfig, task: str, params,
              input_ids, token_type_ids, attention_mask, labels, seed):
    """Loss (mlm/cls) and accuracy (cls only; mlm returns masked accuracy)."""
    batch = {
        "input_ids": input_ids,
        "token_type_ids": token_type_ids,
        "attention_mask": attention_mask,
        "labels": labels,
    }
    key = _rng(seed)
    if task == "cls":
        loss = M.cls_loss(cfg, params, batch, key, train=False)
        acc = M.cls_accuracy(cfg, params, batch, key)
        return loss, acc
    loss = M.mlm_loss(cfg, params, batch, key, train=False)
    return loss, loss * 0.0  # keep a uniform (loss, metric) signature


def make_train_step_fn(cfg: M.ModelConfig, task: str = "mlm"):
    return partial(train_step, cfg, task)


def make_eval_fn(cfg: M.ModelConfig, task: str = "mlm"):
    return partial(eval_step, cfg, task)


def make_init_fn(cfg: M.ModelConfig):
    def init(seed):
        params = M.init_params(cfg, _rng(seed))
        m, v = init_opt_state(params)
        return params, m, v

    return init
