"""L2 layer library: Tempo drop-in replacements as ``jax.custom_vjp``.

Each Tempo layer controls its backward residuals explicitly, so the
lowered HLO retains exactly the tensors the paper's Table/Fig 1 analysis
says it should:

=================  ===============================  =========================
layer              baseline residuals               Tempo residuals
=================  ===============================  =========================
GELU               x (B·S·4H fp)                    y reused + int8 mask
LayerNorm          x (B·S·H fp)                     y reused + rstd (B·S)
softmax (scores)   x and y (2 × B·A·S² fp)          y only
attn dropout       y (B·A·S² fp) + mask             mask only (recompute y)
=================  ===============================  =========================

``impl`` selects the compute path: ``"jnp"`` (fused jnp math — what the
shipped training artifacts use; XLA fuses it into single elementwise
loops) or ``"pallas"`` (the L1 kernels under interpret=True, proving the
kernel path composes; orders slower on CPU, structure-identical).

Baseline twins (plain autodiff) live here too so model.py can build
either variant from one code path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import dropout as drp_k
from .kernels import gelu as gelu_k
from .kernels import layernorm as ln_k
from .kernels import ref
from .kernels import softmax as sm_k

# --------------------------------------------------------------------------
# In-place GELU
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def tempo_gelu(x, impl: str = "jnp"):
    """GELU whose backward runs from (y, mask) — the input is discarded."""
    y, _ = _gelu_fwd_impl(x, impl)
    return y


def _gelu_fwd_impl(x, impl):
    if impl == "pallas":
        return gelu_k.gelu_fwd_pallas(x)
    return gelu_k.gelu_fwd_jnp(x)


def _tempo_gelu_fwd(x, impl):
    y, m = _gelu_fwd_impl(x, impl)
    return y, (y, m)


def _tempo_gelu_bwd(impl, res, dy):
    y, m = res
    if impl == "pallas":
        return (gelu_k.gelu_bwd_pallas(dy, y, m),)
    return (gelu_k.gelu_bwd_jnp(dy, y, m),)


tempo_gelu.defvjp(_tempo_gelu_fwd, _tempo_gelu_bwd)


def baseline_gelu(x):
    """Plain autodiff GELU (residual: x)."""
    return ref.gelu(x)


# --------------------------------------------------------------------------
# In-place LayerNorm
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def tempo_layernorm(x, gamma, beta, eps: float = ln_k.EPS_DEFAULT, impl: str = "jnp"):
    """LayerNorm whose backward runs from (y, rstd, γ, β) — Appendix D."""
    y, _ = _ln_fwd_impl(x, gamma, beta, eps, impl)
    return y


def _ln_fwd_impl(x, gamma, beta, eps, impl):
    if impl == "pallas":
        return ln_k.layernorm_fwd_pallas(x, gamma, beta, eps)
    return ln_k.layernorm_fwd_jnp(x, gamma, beta, eps)


def _tempo_ln_fwd(x, gamma, beta, eps, impl):
    y, rstd = _ln_fwd_impl(x, gamma, beta, eps, impl)
    return y, (y, gamma, beta, rstd)


def _tempo_ln_bwd(eps, impl, res, dy):
    y, gamma, beta, rstd = res
    if impl == "pallas":
        dx, dg, db = ln_k.layernorm_bwd_pallas(dy, y, gamma, beta, rstd)
    else:
        dx, dg, db = ln_k.layernorm_bwd_jnp(dy, y, gamma, beta, rstd)
    return dx, dg, db


tempo_layernorm.defvjp(_tempo_ln_fwd, _tempo_ln_bwd)


def baseline_layernorm(x, gamma, beta, eps: float = ln_k.EPS_DEFAULT):
    return ref.layernorm(x, gamma, beta, eps)


# --------------------------------------------------------------------------
# Output-only softmax
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def tempo_softmax(x, impl: str = "jnp"):
    return _sm_fwd_impl(x, impl)


def _sm_fwd_impl(x, impl):
    if impl == "pallas":
        return sm_k.softmax_fwd_pallas(x)
    return sm_k.softmax_fwd_jnp(x)


def _tempo_sm_fwd(x, impl):
    y = _sm_fwd_impl(x, impl)
    return y, (y,)


def _tempo_sm_bwd(impl, res, dy):
    (y,) = res
    if impl == "pallas":
        return (sm_k.softmax_bwd_pallas(dy, y),)
    return (sm_k.softmax_bwd_jnp(dy, y),)


tempo_softmax.defvjp(_tempo_sm_fwd, _tempo_sm_bwd)


def baseline_softmax(x):
    return ref.softmax(x, axis=-1)


# --------------------------------------------------------------------------
# Dropout (mask passed in; Tempo variant never retains the output)
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def tempo_dropout(x, mask, p: float, impl: str = "jnp"):
    """Dropout retaining only the int8 mask for backward."""
    return _drp_impl(x, mask, p, impl)


def _drp_impl(x, mask, p, impl):
    if p <= 0.0:
        return x
    if impl == "pallas":
        return drp_k.dropout_apply_pallas(x, mask, p)
    return drp_k.dropout_apply_jnp(x, mask, p)


def _tempo_drp_fwd(x, mask, p, impl):
    return _drp_impl(x, mask, p, impl), (mask,)


def _tempo_drp_bwd(p, impl, res, dy):
    (mask,) = res
    return _drp_impl(dy, mask, p, impl), None


tempo_dropout.defvjp(_tempo_drp_fwd, _tempo_drp_bwd)


def baseline_dropout(x, mask, p: float):
    return ref.dropout(x, mask, p)


# --------------------------------------------------------------------------
# Fused Tempo attention core (softmax opt + sub-layer dropout recompute).
# q, k, v: [B, A, S, D]; bias broadcastable [B,1,1,S] or [B,1,S,S];
# mask: [B, A, S, S] int8 keep-mask.
# --------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def tempo_attention(q, k, v, bias, mask, p: float, impl: str = "jnp"):
    ctx, _ = _attn_fwd_impl(q, k, v, bias, mask, p, impl)
    return ctx


def _attn_fwd_impl(q, k, v, bias, mask, p, impl):
    if impl == "pallas":
        return attn_k.attention_fwd_pallas(q, k, v, bias, mask, p)
    return attn_k.attention_fwd_jnp(q, k, v, bias, mask, p)


def _tempo_attn_fwd(q, k, v, bias, mask, p, impl):
    ctx, probs = _attn_fwd_impl(q, k, v, bias, mask, p, impl)
    # Residuals: q, k, v (needed for their own grads — also retained by the
    # baseline), probs and the int8 mask. NOT scores / dropped.
    return ctx, (q, k, v, probs, mask)


def _tempo_attn_bwd(p, impl, res, dctx):
    q, k, v, probs, mask = res
    if impl == "pallas":
        dq, dk, dv = attn_k.attention_bwd_pallas(dctx, q, k, v, probs, mask, p)
    else:
        dq, dk, dv = attn_k.attention_bwd_jnp(dctx, q, k, v, probs, mask, p)
    return dq, dk, dv, None, None


tempo_attention.defvjp(_tempo_attn_fwd, _tempo_attn_bwd)


def baseline_attention(q, k, v, bias, mask, p: float):
    """Plain autodiff attention: retains scores, probs, dropped output."""
    return ref.attention(q, k, v, bias, mask, p)
