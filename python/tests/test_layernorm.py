"""In-place LayerNorm: Appendix D derivation is lossless vs autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import layernorm as ln, ref

from .conftest import assert_allclose


def _inputs(rs, shape, h):
    x = jnp.asarray(rs.randn(*shape, h) * 1.5 + 0.3, jnp.float32)
    gamma = jnp.asarray(rs.rand(h) + 0.5, jnp.float32)
    beta = jnp.asarray(rs.randn(h), jnp.float32)
    return x, gamma, beta


class TestForward:
    def test_fwd_matches_reference(self, rs):
        x, g, b = _inputs(rs, (4, 7), 32)
        y, rstd = ln.layernorm_fwd_jnp(x, g, b)
        assert_allclose(y, ref.layernorm(x, g, b), atol=1e-6)
        _, rstd_ref = ref.layernorm_stats(x)
        assert_allclose(rstd, rstd_ref[..., 0], atol=1e-5)

    def test_fwd_pallas_matches_jnp(self, rs):
        x, g, b = _inputs(rs, (3, 5), 16)
        yp, rp = ln.layernorm_fwd_pallas(x, g, b)
        yj, rj = ln.layernorm_fwd_jnp(x, g, b)
        assert_allclose(yp, yj, atol=1e-5)
        assert_allclose(rp, rj, atol=1e-4, rtol=1e-4)

    def test_rows_are_normalized(self, rs):
        x, g, b = _inputs(rs, (2, 3), 64)
        y, _ = ln.layernorm_fwd_jnp(x, jnp.ones(64), jnp.zeros(64))
        assert np.abs(np.asarray(y.mean(-1))).max() < 1e-5
        assert np.abs(np.asarray(y.std(-1)) - 1.0).max() < 1e-3


class TestBackward:
    def test_bwd_matches_autodiff(self, rs):
        x, g, b = _inputs(rs, (4, 9), 24)
        dy = jnp.asarray(rs.randn(4, 9, 24), jnp.float32)

        def f(x, g, b):
            return jnp.sum(ref.layernorm(x, g, b) * dy)

        dx_t, dg_t, db_t = jax.grad(f, (0, 1, 2))(x, g, b)
        y, rstd = ln.layernorm_fwd_jnp(x, g, b)
        dx, dg, db = ln.layernorm_bwd_jnp(dy, y, g, b, rstd)
        assert_allclose(dx, dx_t, atol=2e-5)
        assert_allclose(dg, dg_t, atol=2e-4, rtol=1e-4)
        assert_allclose(db, db_t, atol=2e-4, rtol=1e-4)

    def test_bwd_pallas_matches_jnp(self, rs):
        x, g, b = _inputs(rs, (6,), 20)
        dy = jnp.asarray(rs.randn(6, 20), jnp.float32)
        y, rstd = ln.layernorm_fwd_jnp(x, g, b)
        dxp, dgp, dbp = ln.layernorm_bwd_pallas(dy, y, g, b, rstd, block_rows=4)
        dxj, dgj, dbj = ln.layernorm_bwd_jnp(dy, y, g, b, rstd)
        assert_allclose(dxp, dxj, atol=1e-5)
        assert_allclose(dgp, dgj, atol=1e-5)
        assert_allclose(dbp, dbj, atol=1e-5)

    def test_closed_form_second_oracle(self, rs):
        # ref.layernorm_bwd_from_output is an independent derivation copy;
        # both must agree (guards against symmetric typos).
        x, g, b = _inputs(rs, (5,), 12)
        dy = jnp.asarray(rs.randn(5, 12), jnp.float32)
        y, rstd = ln.layernorm_fwd_jnp(x, g, b)
        a = ln.layernorm_bwd_jnp(dy, y, g, b, rstd)
        c = ref.layernorm_bwd_from_output(dy, y, g, b, rstd[..., None])
        for u, v in zip(a, c):
            assert_allclose(u, v, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 17),
    h=st.integers(2, 96),
    seed=st.integers(0, 2**31 - 1),
    shift=st.floats(-3.0, 3.0),
)
def test_hypothesis_inplace_ln_equals_autodiff(rows, h, seed, shift):
    """Property: for any (rows, H), output-based LN grads == autodiff."""
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(rows, h) + shift, jnp.float32)
    gamma = jnp.asarray(rs.rand(h) + 0.5, jnp.float32)
    beta = jnp.asarray(rs.randn(h), jnp.float32)
    dy = jnp.asarray(rs.randn(rows, h), jnp.float32)

    def f(x, gamma, beta):
        return jnp.sum(ref.layernorm(x, gamma, beta) * dy)

    dx_t, dg_t, db_t = jax.grad(f, (0, 1, 2))(x, gamma, beta)
    y, rstd = ln.layernorm_fwd_jnp(x, gamma, beta)
    dx, dg, db = ln.layernorm_bwd_jnp(dy, y, gamma, beta, rstd)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_t), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(dg), np.asarray(dg_t), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_t), atol=1e-3, rtol=1e-3)


def test_memory_contract_residuals(rs):
    """The in-place variant's extra stash is O(rows), not O(rows·H)."""
    x, g, b = _inputs(rs, (8, 16), 128)
    _, rstd = ln.layernorm_fwd_jnp(x, g, b)
    assert rstd.shape == (8, 16)  # B×S, last axis dropped
