"""Model-level numerics: variants agree, training improves loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M, train as T

from .conftest import assert_allclose

CFG = M.CONFIGS["tiny"]


def _batch(rs, cfg, b, task="mlm"):
    ids = rs.randint(5, cfg.vocab_size, size=(b, cfg.seq_len)).astype(np.int32)
    labels = np.full((b, cfg.seq_len), -100, np.int32)
    mask_positions = rs.rand(b, cfg.seq_len) < 0.15
    labels[mask_positions] = ids[mask_positions]
    if task == "cls":
        labels = np.full((b, cfg.seq_len), 0, np.int32)
        labels[:, 0] = rs.randint(0, 2, size=b)
    return {
        "input_ids": jnp.asarray(ids),
        "token_type_ids": jnp.zeros((b, cfg.seq_len), jnp.int32),
        "attention_mask": jnp.ones((b, cfg.seq_len), jnp.int32),
        "labels": jnp.asarray(labels),
    }


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


class TestInit:
    def test_param_tree_shape(self, params):
        leaves = jax.tree_util.tree_leaves(params)
        assert len(leaves) == 46  # matches the exported manifests
        total = sum(int(np.prod(l.shape)) for l in leaves)
        assert total > CFG.vocab_size * CFG.hidden  # embeddings dominate

    def test_layernorm_init_is_identity(self, params):
        ln = params["encoder"]["layer_00"]["attn"]["ln"]
        assert (np.asarray(ln["gamma"]) == 1.0).all()
        assert (np.asarray(ln["beta"]) == 0.0).all()


class TestVariantEquivalence:
    """Fig 6a's premise: tempo/checkpoint losses track baseline exactly
    (same masks, same data) up to the GELU approximation."""

    def test_eval_losses_agree_across_variants(self, params, rs):
        batch = _batch(rs, CFG, 4)
        key = jax.random.PRNGKey(9)
        losses = {}
        for variant in M.VARIANTS:
            cfg = CFG.with_variant(variant)
            losses[variant] = float(M.mlm_loss(cfg, params, batch, key, train=False))
        assert abs(losses["baseline"] - losses["checkpoint"]) < 1e-6
        assert abs(losses["baseline"] - losses["tempo"]) < 1e-3

    def test_train_losses_agree_with_shared_masks(self, params, rs):
        batch = _batch(rs, CFG, 4)
        key = jax.random.PRNGKey(11)
        base = float(M.mlm_loss(CFG.with_variant("baseline"), params, batch, key, train=True))
        temp = float(M.mlm_loss(CFG.with_variant("tempo"), params, batch, key, train=True))
        chkp = float(M.mlm_loss(CFG.with_variant("checkpoint"), params, batch, key, train=True))
        assert abs(base - chkp) < 1e-5
        assert abs(base - temp) < 2e-3

    def test_gradients_agree_across_variants(self, params, rs):
        batch = _batch(rs, CFG, 2)
        key = jax.random.PRNGKey(3)

        def gradnorm(cfg):
            g = jax.grad(lambda p: M.mlm_loss(cfg, p, batch, key, train=True))(params)
            return jnp.sqrt(
                sum(jnp.sum(x * x) for x in jax.tree_util.tree_leaves(g))
            )

        gb = float(gradnorm(CFG.with_variant("baseline")))
        gt = float(gradnorm(CFG.with_variant("tempo")))
        gc = float(gradnorm(CFG.with_variant("checkpoint")))
        assert abs(gb - gc) / gb < 1e-4
        assert abs(gb - gt) / gb < 5e-3  # GELU approximation budget


class TestTraining:
    def test_loss_decreases_over_steps(self, rs):
        cfg = CFG.with_variant("tempo")
        step_fn = jax.jit(
            lambda p, m, v, b, s: T.train_step(
                cfg, "mlm", p, m, v,
                b["input_ids"], b["token_type_ids"], b["attention_mask"],
                b["labels"], s, jnp.asarray(0, jnp.int32),
                jnp.asarray(1e-3, jnp.float32),
            )
        )
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        m, v = T.init_opt_state(params)
        batch = _batch(rs, cfg, 4)
        losses = []
        for s in range(8):
            params, m, v, loss = step_fn(params, m, v, batch, jnp.asarray(s, jnp.int32))
            losses.append(float(loss))
        assert losses[-1] < losses[0] - 0.5, losses

    def test_cls_task_loss_and_accuracy(self, params, rs):
        batch = _batch(rs, CFG, 8, task="cls")
        loss, acc = T.eval_step(
            CFG, "cls", params,
            batch["input_ids"], batch["token_type_ids"],
            batch["attention_mask"], batch["labels"],
            jnp.asarray(0, jnp.int32),
        )
        assert 0.0 <= float(acc) <= 1.0
        assert 0.3 < float(loss) < 2.0  # near ln(2) at init

    def test_adamw_moves_every_leaf(self, params):
        grads = jax.tree.map(jnp.ones_like, params)
        m, v = T.init_opt_state(params)
        new_p, new_m, new_v = T.adamw_update(
            params, grads, m, v, jnp.asarray(0, jnp.int32), jnp.asarray(1e-2, jnp.float32)
        )
        moved = jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_p)
        assert all(jax.tree_util.tree_leaves(moved))

    def test_no_decay_on_norm_params(self):
        # weight decay must not leak into gamma/beta/bias updates
        p = {"ln": {"gamma": jnp.ones((4,))}, "w": jnp.ones((4,))}
        g = jax.tree.map(jnp.zeros_like, p)
        m = jax.tree.map(jnp.zeros_like, p)
        v = jax.tree.map(jnp.zeros_like, p)
        np_, _, _ = T.adamw_update(p, g, m, v, jnp.asarray(0, jnp.int32), jnp.asarray(0.1, jnp.float32))
        # zero grad → gamma unchanged; w shrinks by lr*wd*w
        assert (np.asarray(np_["ln"]["gamma"]) == 1.0).all()
        assert (np.asarray(np_["w"]) < 1.0).all()


class TestDropoutDeterminism:
    def test_same_seed_same_loss(self, params, rs):
        batch = _batch(rs, CFG, 2)
        key = jax.random.PRNGKey(17)
        a = float(M.mlm_loss(CFG, params, batch, key, train=True))
        b = float(M.mlm_loss(CFG, params, batch, key, train=True))
        assert a == b

    def test_different_seed_different_loss(self, params, rs):
        batch = _batch(rs, CFG, 2)
        a = float(M.mlm_loss(CFG, params, batch, jax.random.PRNGKey(1), train=True))
        b = float(M.mlm_loss(CFG, params, batch, jax.random.PRNGKey(2), train=True))
        assert a != b


class TestPallasPath:
    """The L1 kernels compose inside the full model (interpret mode)."""

    def test_pallas_model_matches_jnp_model(self, params, rs):
        batch = _batch(rs, CFG, 2)
        key = jax.random.PRNGKey(5)
        jnp_loss = float(
            M.mlm_loss(CFG.with_variant("tempo", "jnp"), params, batch, key, train=True)
        )
        pallas_loss = float(
            M.mlm_loss(CFG.with_variant("tempo", "pallas"), params, batch, key, train=True)
        )
        assert abs(jnp_loss - pallas_loss) < 1e-3, (jnp_loss, pallas_loss)

    def test_pallas_grad_matches_jnp_grad(self, params, rs):
        batch = _batch(rs, CFG, 1)
        key = jax.random.PRNGKey(6)

        def loss_with(impl):
            cfg = CFG.with_variant("tempo", impl)
            g = jax.grad(lambda p: M.mlm_loss(cfg, p, batch, key, train=True))(params)
            return jax.tree_util.tree_leaves(g)

        for a, b in zip(loss_with("jnp"), loss_with("pallas")):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-3, rtol=1e-2)
