"""§5.1 generic in-place elementwise extension vs autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise as ew
from compile.kernels import gelu as gelu_hand


class TestSpecs:
    def test_silu_minimum_found(self):
        # SiLU has a single interior minimum near x ≈ -1.2784645
        assert len(ew.SILU_SPEC.extrema) == 1
        assert abs(ew.SILU_SPEC.extrema[0] + 1.2784645) < 1e-4

    def test_gelu_minimum_matches_hand_kernel(self):
        assert len(ew.GELU_SPEC.extrema) == 1
        assert abs(ew.GELU_SPEC.extrema[0] - gelu_hand.XSTAR) < 1e-6

    def test_fit_error_budgets(self):
        assert ew.SILU_SPEC.max_fit_err < 2e-3
        assert ew.GELU_SPEC.max_fit_err < 2e-3

    def test_branch_count_is_extrema_plus_one(self):
        for spec in (ew.SILU_SPEC, ew.GELU_SPEC):
            assert len(spec.branches) == len(spec.extrema) + 1


class TestIndicator:
    def test_gelu_indicator_matches_mask(self, rs):
        x = jnp.asarray(rs.randn(64) * 2, jnp.float32)
        m = ew.branch_indicator(ew.GELU_SPEC, x)
        _, m_hand = gelu_hand.gelu_fwd_jnp(x)
        assert (np.asarray(m) == np.asarray(m_hand)).all()

    def test_indicator_is_int8(self, rs):
        x = jnp.asarray(rs.randn(8), jnp.float32)
        assert ew.branch_indicator(ew.SILU_SPEC, x).dtype == jnp.int8


class TestGradFromOutput:
    def test_silu_grad_close_to_truth(self):
        x = jnp.asarray(np.linspace(-7, 8, 100001), jnp.float32)
        y = ew.silu_jnp(x)
        m = ew.branch_indicator(ew.SILU_SPEC, x)
        g = ew.grad_from_output(ew.SILU_SPEC, y, m)
        truth = jnp.asarray(ew._dsilu64(np.asarray(x, np.float64)), jnp.float32)
        err = np.abs(np.asarray(g) - np.asarray(truth))
        assert err.max() < 5e-3, err.max()

    def test_gelu_generic_close_to_hand_kernel(self):
        x = jnp.asarray(np.linspace(-6, 8, 50001), jnp.float32)
        y, m = gelu_hand.gelu_fwd_jnp(x)
        g_hand = gelu_hand.DEFAULT_APPROX.g_of_y(y, m)
        g_gen = ew.grad_from_output(ew.GELU_SPEC, y, ew.branch_indicator(ew.GELU_SPEC, x))
        err = np.abs(np.asarray(g_hand) - np.asarray(g_gen))
        assert err.max() < 5e-3, err.max()


class TestLayer:
    def test_inplace_silu_grad_matches_autodiff(self, rs):
        x = jnp.asarray(rs.randn(16, 32) * 2, jnp.float32)
        dy = jnp.asarray(rs.randn(16, 32), jnp.float32)
        dx = jax.grad(lambda t: jnp.sum(ew.inplace_silu(t) * dy))(x)
        dx_true = jax.grad(lambda t: jnp.sum(ew.silu_jnp(t) * dy))(x)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_true), atol=1e-2, rtol=0)

    def test_residuals_are_output_and_int8(self, rs):
        x = jnp.asarray(rs.randn(8, 8), jnp.float32)
        y = ew.silu_jnp(x)
        m = ew.branch_indicator(ew.SILU_SPEC, x)
        # the factory's fwd stores exactly (y, m): reconstructable grads
        g = ew.grad_from_output(ew.SILU_SPEC, y, m)
        assert g.shape == x.shape
        assert m.dtype.itemsize == 1


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(1, 48),
    scale=st.floats(0.2, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_silu_inplace_grads(rows, cols, scale, seed):
    """Property: the §5.1 factory output == autodiff for any shape."""
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(rows, cols) * scale, jnp.float32)
    dy = jnp.asarray(rs.randn(rows, cols), jnp.float32)
    dx = jax.grad(lambda t: jnp.sum(ew.inplace_silu(t) * dy))(x)
    dx_true = jax.grad(lambda t: jnp.sum(ew.silu_jnp(t) * dy))(x)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_true), atol=2e-2, rtol=0)
