"""Output-only softmax + sub-layer dropout recomputation vs autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import dropout as drp, ref, softmax as sm

from .conftest import assert_allclose


class TestSoftmax:
    def test_fwd_matches_reference(self, rs):
        x = jnp.asarray(rs.randn(3, 5, 11) * 3.0, jnp.float32)
        assert_allclose(sm.softmax_fwd_jnp(x), ref.softmax(x), atol=1e-6)

    def test_fwd_is_stable_for_large_logits(self):
        x = jnp.asarray([[1e4, 1e4 - 1.0, -1e4]], jnp.float32)
        y = sm.softmax_fwd_jnp(x)
        assert np.isfinite(np.asarray(y)).all()
        assert abs(float(y.sum()) - 1.0) < 1e-5

    def test_bwd_matches_autodiff(self, rs):
        x = jnp.asarray(rs.randn(4, 9), jnp.float32)
        dy = jnp.asarray(rs.randn(4, 9), jnp.float32)
        dx_t = jax.grad(lambda t: jnp.sum(ref.softmax(t) * dy))(x)
        y = sm.softmax_fwd_jnp(x)
        assert_allclose(sm.softmax_bwd_jnp(dy, y), dx_t, atol=1e-5)

    def test_pallas_matches_jnp(self, rs):
        x = jnp.asarray(rs.randn(7, 13), jnp.float32)
        dy = jnp.asarray(rs.randn(7, 13), jnp.float32)
        assert_allclose(sm.softmax_fwd_pallas(x, block_rows=4), sm.softmax_fwd_jnp(x), atol=1e-6)
        y = sm.softmax_fwd_jnp(x)
        assert_allclose(sm.softmax_bwd_pallas(dy, y, block_rows=4), sm.softmax_bwd_jnp(dy, y), atol=1e-6)


class TestDropout:
    def test_mask_rate(self):
        key = jax.random.PRNGKey(1)
        m = drp.make_mask(key, (512, 512), 0.1)
        keep = float(np.asarray(m, np.float64).mean())
        assert abs(keep - 0.9) < 0.01
        assert m.dtype == jnp.int8  # the paper's 8-bit bool (footnote 3)

    def test_apply_scales_kept_entries(self, rs):
        x = jnp.asarray(rs.randn(8, 8), jnp.float32)
        m = drp.make_mask(jax.random.PRNGKey(0), (8, 8), 0.25)
        y = drp.dropout_apply_jnp(x, m, 0.25)
        expect = np.asarray(x) * np.asarray(m) / 0.75
        assert_allclose(y, expect, atol=1e-6)

    def test_recomputation_is_exact(self, rs):
        """The crux of §3.3: recomputed output == discarded output."""
        x = jnp.asarray(rs.randn(16, 16), jnp.float32)
        m = drp.make_mask(jax.random.PRNGKey(3), (16, 16), 0.1)
        first = drp.dropout_apply_jnp(x, m, 0.1)
        recomputed = drp.dropout_apply_jnp(x, m, 0.1)
        assert (np.asarray(first) == np.asarray(recomputed)).all()

    def test_bwd_matches_autodiff(self, rs):
        x = jnp.asarray(rs.randn(6, 10), jnp.float32)
        dy = jnp.asarray(rs.randn(6, 10), jnp.float32)
        m = drp.make_mask(jax.random.PRNGKey(5), (6, 10), 0.2)
        dx_t = jax.grad(lambda t: jnp.sum(ref.dropout(t, m, 0.2) * dy))(x)
        assert_allclose(drp.dropout_bwd_jnp(dy, m, 0.2), dx_t, atol=1e-6)

    def test_p_zero_is_identity(self, rs):
        x = jnp.asarray(rs.randn(4, 4), jnp.float32)
        m = jnp.ones((4, 4), jnp.int8)
        assert (np.asarray(drp.dropout_apply_jnp(x, m, 0.0)) == np.asarray(x)).all()

    def test_pallas_matches_jnp(self, rs):
        x = jnp.asarray(rs.randn(9, 12), jnp.float32)
        m = drp.make_mask(jax.random.PRNGKey(7), (9, 12), 0.3)
        assert_allclose(
            drp.dropout_apply_pallas(x, m, 0.3, block_rows=4),
            drp.dropout_apply_jnp(x, m, 0.3),
            atol=1e-6,
        )

    def test_memory_contract(self):
        """Mask is 1 byte/elt; output (4 bytes/elt) is discardable → 4/5 saved."""
        m = drp.make_mask(jax.random.PRNGKey(0), (10, 10), 0.1)
        assert m.dtype.itemsize * m.size == 100
        # float output would be 400 bytes; keeping only the mask saves 4/5
        assert 1.0 - 100 / 500 == 0.8


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(2, 64),
    scale=st.floats(0.1, 8.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_softmax_output_only_bwd(rows, cols, scale, seed):
    """Property: output-only softmax backward == autodiff for any shape."""
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(rows, cols) * scale, jnp.float32)
    dy = jnp.asarray(rs.randn(rows, cols), jnp.float32)
    dx_t = jax.grad(lambda t: jnp.sum(ref.softmax(t) * dy))(x)
    y = sm.softmax_fwd_jnp(x)
    np.testing.assert_allclose(
        np.asarray(sm.softmax_bwd_jnp(dy, y)), np.asarray(dx_t), atol=1e-4, rtol=1e-4
    )


@settings(max_examples=25, deadline=None)
@given(p=st.floats(0.0, 0.9), seed=st.integers(0, 2**31 - 1))
def test_hypothesis_dropout_grad_any_rate(p, seed):
    """Property: mask-only dropout backward == autodiff for any rate."""
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(8, 8), jnp.float32)
    dy = jnp.asarray(rs.randn(8, 8), jnp.float32)
    m = drp.make_mask(jax.random.PRNGKey(seed), (8, 8), p)
    dx_t = jax.grad(lambda t: jnp.sum(ref.dropout(t, m, p) * dy))(x)
    np.testing.assert_allclose(
        np.asarray(drp.dropout_bwd_jnp(dy, m, p)), np.asarray(dx_t), atol=1e-5, rtol=1e-5
    )
