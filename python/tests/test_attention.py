"""Fused Tempo attention core vs autodiff reference."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import layers as L
from compile.kernels import attention as attn, dropout as drp, ref

from .conftest import assert_allclose


def _qkv(rs, b=2, h=2, s=8, d=4):
    mk = lambda: jnp.asarray(rs.randn(b, h, s, d), jnp.float32)  # noqa: E731
    bias = jnp.asarray(rs.randn(b, 1, 1, s) * 0.1, jnp.float32)
    return mk(), mk(), mk(), bias


class TestForward:
    def test_fwd_matches_reference(self, rs):
        q, k, v, bias = _qkv(rs)
        m = drp.make_mask(jax.random.PRNGKey(0), (2, 2, 8, 8), 0.1)
        ctx, probs = attn.attention_fwd_jnp(q, k, v, bias, m, 0.1)
        ctx_ref = ref.attention(q, k, v, bias, m, 0.1)
        assert_allclose(ctx, ctx_ref, atol=1e-5)
        assert probs.shape == (2, 2, 8, 8)

    def test_probs_rowsum_one(self, rs):
        q, k, v, bias = _qkv(rs)
        m = jnp.ones((2, 2, 8, 8), jnp.int8)
        _, probs = attn.attention_fwd_jnp(q, k, v, bias, m, 0.0)
        assert_allclose(probs.sum(-1), jnp.ones((2, 2, 8)), atol=1e-5)

    def test_padding_mask_zeroes_attention(self, rs):
        q, k, v, _ = _qkv(rs)
        # mask out the last 3 keys
        am = jnp.concatenate([jnp.ones((2, 5)), jnp.zeros((2, 3))], axis=1)
        bias = (1.0 - am[:, None, None, :]) * ref.jnp.asarray(-1e9, jnp.float32)
        m = jnp.ones((2, 2, 8, 8), jnp.int8)
        _, probs = attn.attention_fwd_jnp(q, k, v, bias, m, 0.0)
        assert float(np.asarray(probs)[..., 5:].max()) < 1e-6

    def test_fwd_pallas_matches_jnp(self, rs):
        q, k, v, bias = _qkv(rs)
        m = drp.make_mask(jax.random.PRNGKey(2), (2, 2, 8, 8), 0.1)
        cp, pp = attn.attention_fwd_pallas(q, k, v, bias, m, 0.1)
        cj, pj = attn.attention_fwd_jnp(q, k, v, bias, m, 0.1)
        assert_allclose(cp, cj, atol=1e-5)
        assert_allclose(pp, pj, atol=1e-5)


class TestBackward:
    def test_bwd_matches_autodiff(self, rs):
        q, k, v, bias = _qkv(rs)
        m = drp.make_mask(jax.random.PRNGKey(1), (2, 2, 8, 8), 0.1)
        dctx = jnp.asarray(rs.randn(2, 2, 8, 4), jnp.float32)

        def f(q, k, v):
            return jnp.sum(ref.attention(q, k, v, bias, m, 0.1) * dctx)

        dq_t, dk_t, dv_t = jax.grad(f, (0, 1, 2))(q, k, v)
        _, probs = attn.attention_fwd_jnp(q, k, v, bias, m, 0.1)
        dq, dk, dv = attn.attention_bwd_jnp(dctx, q, k, v, probs, m, 0.1)
        assert_allclose(dq, dq_t, atol=1e-5)
        assert_allclose(dk, dk_t, atol=1e-5)
        assert_allclose(dv, dv_t, atol=1e-5)

    def test_bwd_pallas_matches_jnp(self, rs):
        q, k, v, bias = _qkv(rs)
        m = drp.make_mask(jax.random.PRNGKey(4), (2, 2, 8, 8), 0.2)
        dctx = jnp.asarray(rs.randn(2, 2, 8, 4), jnp.float32)
        _, probs = attn.attention_fwd_jnp(q, k, v, bias, m, 0.2)
        outs_p = attn.attention_bwd_pallas(dctx, q, k, v, probs, m, 0.2)
        outs_j = attn.attention_bwd_jnp(dctx, q, k, v, probs, m, 0.2)
        for a, b in zip(outs_p, outs_j):
            assert_allclose(a, b, atol=1e-5)

    def test_custom_vjp_layer_matches_autodiff(self, rs):
        q, k, v, bias = _qkv(rs)
        m = drp.make_mask(jax.random.PRNGKey(6), (2, 2, 8, 8), 0.1)

        f_t = lambda q, k, v: (L.tempo_attention(q, k, v, bias, m, 0.1) ** 2).sum()  # noqa: E731
        f_b = lambda q, k, v: (ref.attention(q, k, v, bias, m, 0.1) ** 2).sum()  # noqa: E731
        gt = jax.grad(f_t, (0, 1, 2))(q, k, v)
        gb = jax.grad(f_b, (0, 1, 2))(q, k, v)
        for a, b in zip(gt, gb):
            assert_allclose(a, b, atol=1e-5)


class TestResiduals:
    def test_tempo_saves_probs_and_mask_only(self, rs):
        """Structural check: the custom_vjp residual tuple holds q,k,v,
        probs and the int8 mask — no scores, no dropped output."""
        q, k, v, bias = _qkv(rs)
        m = drp.make_mask(jax.random.PRNGKey(7), (2, 2, 8, 8), 0.1)
        from compile.layers import _tempo_attn_fwd

        _, res = _tempo_attn_fwd(q, k, v, bias, m, 0.1, "jnp")
        assert len(res) == 5
        float_maps = [r for r in res if r.dtype == jnp.float32 and r.ndim == 4 and r.shape[-1] == r.shape[-2]]
        assert len(float_maps) == 1  # probs only — not scores/dropped
        int_maps = [r for r in res if r.dtype == jnp.int8]
        assert len(int_maps) == 1  # the 1-byte mask


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    s=st.integers(2, 12),
    d=st.integers(1, 8),
    p=st.sampled_from([0.0, 0.1, 0.5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_attention_grads(b, h, s, d, p, seed):
    """Property: Tempo attention backward == autodiff over shape space."""
    rs = np.random.RandomState(seed)
    q = jnp.asarray(rs.randn(b, h, s, d), jnp.float32)
    k = jnp.asarray(rs.randn(b, h, s, d), jnp.float32)
    v = jnp.asarray(rs.randn(b, h, s, d), jnp.float32)
    bias = jnp.zeros((b, 1, 1, s), jnp.float32)
    m = drp.make_mask(jax.random.PRNGKey(seed), (b, h, s, s), p)
    dctx = jnp.asarray(rs.randn(b, h, s, d), jnp.float32)

    def f(q, k, v):
        return jnp.sum(ref.attention(q, k, v, bias, m, p) * dctx)

    dq_t, dk_t, dv_t = jax.grad(f, (0, 1, 2))(q, k, v)
    _, probs = attn.attention_fwd_jnp(q, k, v, bias, m, p)
    dq, dk, dv = attn.attention_bwd_jnp(dctx, q, k, v, probs, m, p)
    for a, t in ((dq, dq_t), (dk, dk_t), (dv, dv_t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(t), atol=1e-4, rtol=1e-4)
