"""In-place GELU: inverse-composition approximation + kernels vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gelu, ref

from .conftest import assert_allclose

APPROX_TOL = 2e-3  # the paper's "tunable lossy" budget; we land well under


class TestMinimum:
    def test_xstar_is_a_critical_point(self):
        # float64 oracle (jax runs in f32 here, so use the numpy fitter's)
        g = float(gelu._gelu_grad64(np.asarray(gelu.XSTAR)))
        assert abs(g) < 1e-9

    def test_ystar_matches_gelu_at_xstar(self):
        y = float(gelu._gelu64(np.asarray(gelu.XSTAR)))
        assert abs(y - gelu.YSTAR) < 1e-12

    def test_minimum_is_global_on_grid(self):
        xs = jnp.linspace(-10, 10, 100001)
        ys = ref.gelu(xs)
        assert float(ys.min()) >= gelu.YSTAR - 1e-6


class TestApproximation:
    def test_fit_error_budgets(self):
        ap = gelu.GeluApprox.fit()
        assert ap.max_err_pos < 1e-6
        assert ap.max_err_neg < 1e-3

    def test_g_of_y_matches_true_derivative_densely(self):
        ap = gelu.DEFAULT_APPROX
        x = jnp.asarray(np.linspace(-8.0, 10.0, 200001), jnp.float32)
        y, m = gelu.gelu_fwd_jnp(x)
        g = ap.g_of_y(y, m)
        err = np.abs(np.asarray(g) - np.asarray(ref.gelu_grad(x)))
        assert err.max() < APPROX_TOL, f"max err {err.max()}"

    def test_tunable_tradeoff_more_segments_less_error(self):
        lo = gelu.GeluApprox.fit(degree=5, n_seg_pos=2, n_seg_neg=2)
        hi = gelu.GeluApprox.fit(degree=11, n_seg_pos=8, n_seg_neg=8)
        assert hi.max_err_pos <= lo.max_err_pos
        assert hi.max_err_neg <= lo.max_err_neg

    def test_positive_tail_is_analytic(self):
        # beyond Y_HI the derivative comes from GELU'(y) directly
        x = jnp.asarray([7.0, 9.0, 25.0], jnp.float32)
        y, m = gelu.gelu_fwd_jnp(x)
        g = gelu.DEFAULT_APPROX.g_of_y(y, m)
        assert_allclose(g, ref.gelu_grad(x), atol=1e-6)

    def test_negative_tail_clamps_to_zero(self):
        x = jnp.asarray([-6.0, -12.0], jnp.float32)
        y, m = gelu.gelu_fwd_jnp(x)
        g = gelu.DEFAULT_APPROX.g_of_y(y, m)
        assert np.abs(np.asarray(g)).max() < 1e-3


class TestForward:
    def test_fwd_jnp_matches_reference(self, rs):
        x = jnp.asarray(rs.randn(4, 33, 65), jnp.float32)
        y, m = gelu.gelu_fwd_jnp(x)
        assert_allclose(y, ref.gelu(x), atol=1e-6)
        assert m.dtype == jnp.int8

    def test_mask_semantics(self):
        x = jnp.asarray([-3.0, gelu.XSTAR - 1e-3, gelu.XSTAR + 1e-3, 0.0, 5.0], jnp.float32)
        _, m = gelu.gelu_fwd_jnp(x)
        assert list(np.asarray(m)) == [0, 0, 1, 1, 1]

    def test_fwd_pallas_matches_jnp(self, rs):
        x = jnp.asarray(rs.randn(3, 17, 32), jnp.float32)
        yp, mp = gelu.gelu_fwd_pallas(x)
        yj, mj = gelu.gelu_fwd_jnp(x)
        assert_allclose(yp, yj, atol=1e-6)
        assert (np.asarray(mp) == np.asarray(mj)).all()


class TestBackward:
    def test_bwd_jnp_matches_input_based(self, rs):
        x = jnp.asarray(rs.randn(8, 64) * 2.0, jnp.float32)
        dy = jnp.asarray(rs.randn(8, 64), jnp.float32)
        y, m = gelu.gelu_fwd_jnp(x)
        dx = gelu.gelu_bwd_jnp(dy, y, m)
        dx_ref = ref.gelu_bwd_from_input(dy, x)
        assert_allclose(dx, dx_ref, atol=5 * APPROX_TOL, rtol=0)

    def test_bwd_pallas_matches_jnp(self, rs):
        x = jnp.asarray(rs.randn(5, 40) * 2.0, jnp.float32)
        dy = jnp.asarray(rs.randn(5, 40), jnp.float32)
        y, m = gelu.gelu_fwd_jnp(x)
        assert_allclose(
            gelu.gelu_bwd_pallas(dy, y, m),
            gelu.gelu_bwd_jnp(dy, y, m),
            atol=1e-4,
        )

    def test_memory_contract_mask_is_one_byte(self, rs):
        x = jnp.asarray(rs.randn(16, 16), jnp.float32)
        _, m = gelu.gelu_fwd_jnp(x)
        assert m.dtype.itemsize == 1  # paper footnote 3


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(1, 33),
    cols=st.integers(1, 65),
    scale=st.floats(0.1, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_gelu_bwd_close_to_autodiff(rows, cols, scale, seed):
    """Property: for any shape/scale, Tempo GELU grad ≈ autodiff grad."""
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(rows, cols) * scale, jnp.float32)
    dy = jnp.asarray(rs.randn(rows, cols), jnp.float32)
    y, m = gelu.gelu_fwd_jnp(x)
    dx = gelu.gelu_bwd_jnp(dy, y, m)
    dx_true = ref.gelu_bwd_from_input(dy, x)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_true), atol=2e-2, rtol=0)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 8),
    cols=st.sampled_from([1, 7, 16, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_pallas_fwd_any_shape(rows, cols, seed):
    """Property: pallas fwd handles non-multiple-of-block shapes."""
    rs = np.random.RandomState(seed)
    x = jnp.asarray(rs.randn(rows, cols), jnp.float32)
    yp, mp = gelu.gelu_fwd_pallas(x, block_rows=4)
    yj, mj = gelu.gelu_fwd_jnp(x)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(yj), atol=1e-6)
    assert (np.asarray(mp) == np.asarray(mj)).all()


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-3), (jnp.bfloat16, 4e-2)])
def test_dtype_sweep(dtype, tol, rs):
    x = jnp.asarray(rs.randn(64, 64), dtype)
    y, m = gelu.gelu_fwd_jnp(x)
    g = gelu.DEFAULT_APPROX.g_of_y(y, m)
    gt = ref.gelu_grad(x.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(g, dtype=np.float32), np.asarray(gt), atol=tol, rtol=0
    )
