import numpy as np
import pytest


@pytest.fixture
def rs():
    """Deterministic numpy RandomState per test."""
    return np.random.RandomState(0)


def assert_allclose(a, b, atol=1e-5, rtol=1e-5, msg=""):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol, rtol=rtol,
                               err_msg=msg)
