"""AOT pipeline: manifests consistent, HLO text parseable and erf-free."""

import json
import pathlib

import pytest

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ARTIFACTS / "index.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)

# Opcodes the image's xla_extension 0.5.1 HLO parser is known to reject.
FORBIDDEN_OPCODES = (" erf(", " tan(", " topk(", " stochastic-convert(")


def _artifact_dirs():
    index = json.loads((ARTIFACTS / "index.json").read_text())
    return [ARTIFACTS / e["dir"] for e in index]


def test_index_lists_all_artifacts():
    names = {e["name"] for e in json.loads((ARTIFACTS / "index.json").read_text())}
    assert {"bert_tiny_baseline", "bert_tiny_checkpoint", "bert_tiny_tempo",
            "pallas_smoke"} <= names


@pytest.mark.parametrize("adir", _artifact_dirs(), ids=lambda p: p.name)
def test_manifest_and_files(adir):
    manifest = json.loads((adir / "manifest.json").read_text())
    assert manifest["n_param_leaves"] == len(manifest["params"])
    for f in manifest["files"].values():
        path = adir / f
        assert path.exists() and path.stat().st_size > 1000
    # ABI: 4 batch inputs in canonical order
    assert [b["name"] for b in manifest["batch_inputs"]] == [
        "input_ids", "token_type_ids", "attention_mask", "labels",
    ]


@pytest.mark.parametrize("adir", _artifact_dirs(), ids=lambda p: p.name)
def test_hlo_text_is_old_parser_safe(adir):
    """Regression guard: no opcodes newer than the rust-side XLA parser."""
    for f in ("init.hlo.txt", "step.hlo.txt", "eval.hlo.txt"):
        text = (adir / f).read_text()
        assert text.startswith("HloModule"), f"{adir.name}/{f} is not HLO text"
        for op in FORBIDDEN_OPCODES:
            assert op not in text, f"{adir.name}/{f} contains {op.strip()}"


def test_step_entry_arity():
    """step takes 3n leaves + 4 batch tensors + 3 scalars."""
    adir = ARTIFACTS / "bert_tiny_tempo"
    manifest = json.loads((adir / "manifest.json").read_text())
    n = manifest["n_param_leaves"]
    text = (adir / "step.hlo.txt").read_text()
    # count entry parameters in the ENTRY computation signature
    entry = text.split("ENTRY")[1]
    first_line = entry.split("\n")[0]
    n_params = first_line.count("parameter") if "parameter" in first_line else None
    # fall back: count `parameter(k)` instructions
    import re

    ids = re.findall(r"parameter\((\d+)\)", text)
    assert len(set(ids)) == 3 * n + 4 + 3


def test_variants_share_abi():
    """baseline/checkpoint/tempo tiny artifacts expose identical ABIs."""
    manifests = [
        json.loads((ARTIFACTS / f"bert_tiny_{v}" / "manifest.json").read_text())
        for v in ("baseline", "checkpoint", "tempo")
    ]
    specs = [[(p["name"], tuple(p["shape"])) for p in m["params"]] for m in manifests]
    assert specs[0] == specs[1] == specs[2]
